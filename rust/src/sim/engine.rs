//! Discrete-event simulator: executes a [`Schedule`] against the
//! [`CostModel`](super::costmodel::CostModel) on a modeled cluster.
//!
//! Each stage has a FIFO **compute stream** (Fwd/Bwd) and each
//! evictor/acceptor pair a FIFO **transfer stream** (Evict/Load).  Ops
//! form a DAG:
//!
//! * `Fwd(s, i)` needs `Fwd(s−1, i)` (activation arrival) and the
//!   previous compute op on stage `s`;
//! * `Bwd(s, i)` needs `Bwd(s+1, i)` (gradient arrival), its own
//!   `Fwd(s, i)`, the previous compute op, and — if the stash was
//!   evicted — `Load(s, i)` (BPipe's only coupling into compute);
//! * `Evict/Load` need their triggering op and the previous transfer on
//!   the pair's link.
//!
//! Completion times are computed by Kahn topological order; the engine
//! also tracks per-device stash residency over time (memory high-water,
//! OOM detection) and per-stream busy time (bubble fraction).

use super::costmodel::CostModel;
use crate::bpipe::{pairing, Layout};
use crate::config::ExperimentConfig;
use crate::model::{flops, memory::MemoryModel};
use crate::schedule::{OpKind, Schedule};

/// One executed op, for timeline rendering (paper Figure 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    pub stage: u64,
    pub kind: OpKind,
    pub mb: u64,
    pub chunk: u64,
    pub start: f64,
    pub end: f64,
}

/// Simulation output for one training iteration.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// iteration wall-clock (seconds)
    pub makespan: f64,
    /// whole-model MFU (0..1), paper Eq. MFU definition
    pub mfu: f64,
    /// per-stage compute busy time (seconds)
    pub busy: Vec<f64>,
    /// 1 − mean(busy)/makespan
    pub bubble_fraction: f64,
    /// per-stage peak device memory, bytes (weights+opt+stash+reserved)
    pub mem_high_water: Vec<u64>,
    /// stage that exceeded HBM capacity, if any
    pub oom_stage: Option<u64>,
    /// total backward stall time waiting on BPipe loads (seconds)
    pub load_stall: f64,
    /// total bytes moved by BPipe transfers
    pub transfer_bytes: u64,
    /// executed-op timeline
    pub trace: Vec<TraceEvent>,
}

impl SimResult {
    pub fn mfu_pct(&self) -> f64 {
        self.mfu * 100.0
    }
}

/// Export a trace as CSV (`stage,kind,mb,chunk,start,end`) for external
/// plotting — the machine-readable companion of the Figure-1 renderer.
pub fn trace_to_csv(trace: &[TraceEvent]) -> String {
    let mut out = String::from("stage,kind,mb,chunk,start,end\n");
    for ev in trace {
        out.push_str(&format!(
            "{},{:?},{},{},{:.9},{:.9}\n",
            ev.stage, ev.kind, ev.mb, ev.chunk, ev.start, ev.end
        ));
    }
    out
}

#[derive(Clone, Copy)]
struct Node {
    stage: usize,
    idx: usize,
}

/// Simulate one iteration of `schedule` for experiment `e` on `layout`.
pub fn simulate(e: &ExperimentConfig, schedule: &Schedule, layout: &Layout) -> SimResult {
    crate::schedule::validate(schedule).expect("refusing to simulate an invalid schedule");
    let cm = CostModel::new(e);
    let mm = MemoryModel::new(e);
    let p = schedule.p as usize;
    let chunks = match schedule.kind {
        crate::schedule::ScheduleKind::Interleaved { chunks } => chunks,
        _ => 1,
    };

    // -- global node ids ---------------------------------------------------
    let mut base = vec![0usize; p + 1];
    for s in 0..p {
        base[s + 1] = base[s] + schedule.programs[s].ops.len();
    }
    let n = base[p];
    let node_of = |s: usize, idx: usize| base[s] + idx;
    let nodes: Vec<Node> = (0..p)
        .flat_map(|s| (0..schedule.programs[s].ops.len()).map(move |idx| Node { stage: s, idx }))
        .collect();

    // index (stage, kind, mb, chunk) -> node id, for dependency lookups
    let mut find: std::collections::HashMap<(usize, OpKind, u64, u64), usize> =
        std::collections::HashMap::with_capacity(n);
    for (id, nd) in nodes.iter().enumerate() {
        let op = schedule.programs[nd.stage].ops[nd.idx];
        find.insert((nd.stage, op.kind, op.mb, op.chunk), id);
    }

    // -- dependency edges ---------------------------------------------------
    let mut deps: Vec<Vec<usize>> = vec![Vec::with_capacity(3); n];
    // FIFO streams: previous compute op per stage; previous transfer per
    // LINK.  An intra-node pair gets a dedicated NVLink p2p stream; every
    // cross-node pair whose evictor sits on the same node contends for
    // that node's single IB uplink (the effect paper Figure 2's
    // pair-adjacent layout exists to avoid).
    #[derive(Hash, PartialEq, Eq, Clone, Copy)]
    enum LinkKey {
        NvlinkPair(usize),
        IbUplink(u64),
    }
    let link_of = |stage: usize| -> LinkKey {
        if layout.pair_intra_node(p as u64, stage as u64) {
            LinkKey::NvlinkPair(stage.min(p - 1 - stage))
        } else {
            LinkKey::IbUplink(layout.node_of(stage as u64))
        }
    };
    let mut prev_compute: Vec<Option<usize>> = vec![None; p];
    for (id, nd) in nodes.iter().enumerate() {
        let s = nd.stage;
        let op = schedule.programs[s].ops[nd.idx];
        match op.kind {
            OpKind::Fwd => {
                if let Some(prev) = prev_compute[s] {
                    deps[id].push(prev);
                }
                // activation arrival: previous (virtual) stage's fwd
                if s > 0 {
                    deps[id].push(find[&(s - 1, OpKind::Fwd, op.mb, op.chunk)]);
                } else if op.chunk > 0 {
                    // interleaved wrap: chunk c at stage 0 consumes
                    // chunk c−1 at stage p−1
                    deps[id].push(find[&(p - 1, OpKind::Fwd, op.mb, op.chunk - 1)]);
                }
                prev_compute[s] = Some(id);
            }
            OpKind::Bwd => {
                if let Some(prev) = prev_compute[s] {
                    deps[id].push(prev);
                }
                deps[id].push(find[&(s, OpKind::Fwd, op.mb, op.chunk)]);
                if s + 1 < p {
                    deps[id].push(find[&(s + 1, OpKind::Bwd, op.mb, op.chunk)]);
                } else if op.chunk + 1 < chunks {
                    // interleaved wrap: grad for chunk c at stage p−1
                    // comes from chunk c+1 at stage 0
                    deps[id].push(find[&(0, OpKind::Bwd, op.mb, op.chunk + 1)]);
                }
                if let Some(&load) = find.get(&(s, OpKind::Load, op.mb, op.chunk)) {
                    deps[id].push(load);
                }
                prev_compute[s] = Some(id);
            }
            OpKind::Evict | OpKind::Load => {
                // issue point: the op preceding it in program order
                if nd.idx > 0 {
                    deps[id].push(node_of(s, nd.idx - 1));
                }
                if op.kind == OpKind::Load {
                    deps[id].push(find[&(s, OpKind::Evict, op.mb, op.chunk)]);
                }
                // link arbitration is time-based (FCFS per link) in the
                // event loop below, not a static dependency — static
                // chaining of a *shared* uplink across stages can create
                // artificial cycles.
            }
        }
    }

    // -- durations ----------------------------------------------------------
    let stage_times: Vec<_> = (0..p).map(|s| cm.stage_times(s as u64)).collect();
    // interleaved chunks split a stage's layers v ways
    let chunk_scale = 1.0 / chunks as f64;
    let dur = |nd: &Node| -> f64 {
        let op = schedule.programs[nd.stage].ops[nd.idx];
        match op.kind {
            OpKind::Fwd => stage_times[nd.stage].fwd * chunk_scale,
            OpKind::Bwd => stage_times[nd.stage].bwd * chunk_scale,
            OpKind::Evict | OpKind::Load => {
                let intra = layout.pair_intra_node(p as u64, nd.stage as u64);
                cm.transfer_time(intra)
            }
        }
    };

    // -- event-driven timing with FCFS link arbitration ----------------------
    // Ops become READY when all logical deps complete; compute ops start
    // at their ready time (program-order deps already serialize the
    // stage's compute stream); transfer ops additionally queue FCFS on
    // their link.  Events are processed in ready-time order, which makes
    // the link free-time bookkeeping causally consistent.
    let mut indeg = vec![0usize; n];
    let mut rev: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (id, ds) in deps.iter().enumerate() {
        indeg[id] = ds.len();
        for &d in ds {
            rev[d].push(id);
        }
    }
    let mut start = vec![0f64; n];
    let mut end = vec![0f64; n];
    // BinaryHeap over (ready_time, id); f64 wrapped for total order
    #[derive(PartialEq)]
    struct Ev(f64, usize);
    impl Eq for Ev {}
    impl PartialOrd for Ev {
        fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(other))
        }
    }
    impl Ord for Ev {
        fn cmp(&self, other: &Self) -> std::cmp::Ordering {
            // min-heap: reverse on time, tie-break on id for determinism
            other
                .0
                .partial_cmp(&self.0)
                .unwrap()
                .then(other.1.cmp(&self.1))
        }
    }
    let mut heap: std::collections::BinaryHeap<Ev> = (0..n)
        .filter(|&i| indeg[i] == 0)
        .map(|i| Ev(0.0, i))
        .collect();
    let mut link_free: std::collections::HashMap<LinkKey, f64> = Default::default();
    let mut done = 0usize;
    let mut load_stall = 0f64;
    while let Some(Ev(ready, id)) = heap.pop() {
        done += 1;
        let nd = nodes[id];
        let op = schedule.programs[nd.stage].ops[nd.idx];
        let t0 = match op.kind {
            OpKind::Evict | OpKind::Load => {
                let link = link_of(nd.stage);
                let free = link_free.entry(link).or_insert(0.0);
                let s = ready.max(*free);
                *free = s + dur(&nd);
                s
            }
            _ => ready,
        };
        start[id] = t0;
        end[id] = t0 + dur(&nd);
        if op.kind == OpKind::Bwd {
            if let Some(&load) = find.get(&(nd.stage, OpKind::Load, op.mb, op.chunk)) {
                let without: f64 = deps[id]
                    .iter()
                    .filter(|&&d| d != load)
                    .map(|&d| end[d])
                    .fold(0f64, f64::max);
                load_stall += (end[load] - without).max(0.0);
            }
        }
        for &nxt in &rev[id] {
            indeg[nxt] -= 1;
            if indeg[nxt] == 0 {
                let r = deps[nxt].iter().map(|&d| end[d]).fold(0f64, f64::max);
                heap.push(Ev(r, nxt));
            }
        }
    }
    assert_eq!(done, n, "dependency cycle in schedule DAG");

    // -- aggregate ------------------------------------------------------------
    let makespan = end.iter().cloned().fold(0f64, f64::max);
    let mut busy = vec![0f64; p];
    let mut trace = Vec::with_capacity(n);
    for (id, nd) in nodes.iter().enumerate() {
        let op = schedule.programs[nd.stage].ops[nd.idx];
        if matches!(op.kind, OpKind::Fwd | OpKind::Bwd) {
            busy[nd.stage] += end[id] - start[id];
        }
        trace.push(TraceEvent {
            stage: nd.stage as u64,
            kind: op.kind,
            mb: op.mb,
            chunk: op.chunk,
            start: start[id],
            end: end[id],
        });
    }
    trace.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());

    // -- memory timeline -------------------------------------------------------
    // events: (time, stage, delta_stashes); stash bytes are uniform
    let act = mm.activation_bytes_per_microbatch(0);
    let mut events: Vec<(f64, usize, i64)> = Vec::new();
    for (id, nd) in nodes.iter().enumerate() {
        let op = schedule.programs[nd.stage].ops[nd.idx];
        let partner = pairing::partner(p as u64, nd.stage as u64) as usize;
        match op.kind {
            OpKind::Fwd => events.push((end[id], nd.stage, 1)),
            OpKind::Bwd => events.push((end[id], nd.stage, -1)),
            OpKind::Evict => {
                // freed locally only once the transfer lands; acceptor
                // allocates at transfer start (conservative overlap)
                events.push((end[id], nd.stage, -1));
                events.push((start[id], partner, 1));
            }
            OpKind::Load => {
                events.push((start[id], nd.stage, 1));
                events.push((end[id], partner, -1));
            }
        }
    }
    events.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.2.cmp(&b.2)));
    let mut cur = vec![0i64; p];
    let mut hw = vec![0i64; p];
    for (_, s, d) in events {
        cur[s] += d;
        hw[s] = hw[s].max(cur[s]);
    }
    let mem_high_water: Vec<u64> = (0..p)
        .map(|s| {
            mm.weight_opt_bytes(s as u64) + e.cluster.reserved_bytes + hw[s] as u64 * act
        })
        .collect();
    let oom_stage = mem_high_water
        .iter()
        .position(|&b| b > e.cluster.hbm_bytes)
        .map(|s| s as u64);

    let transfers = schedule
        .programs
        .iter()
        .flat_map(|pr| pr.ops.iter())
        .filter(|o| matches!(o.kind, OpKind::Evict | OpKind::Load))
        .count() as u64;

    let model_flops = flops::model_flops_per_iteration(&e.model, e.parallel.global_batch);
    let devices = e.parallel.devices() as f64;
    let mfu = model_flops / (devices * e.cluster.peak_flops * makespan);
    let mean_busy: f64 = busy.iter().sum::<f64>() / p as f64;

    SimResult {
        makespan,
        mfu,
        bubble_fraction: 1.0 - mean_busy / makespan,
        busy,
        mem_high_water,
        oom_stage,
        load_stall,
        transfer_bytes: transfers * act,
        trace,
    }
}

/// Build the schedule an experiment config implies (1F1B, +BPipe if
/// enabled) with the pair-adjacent layout, simulate one iteration.
pub fn simulate_experiment(e: &ExperimentConfig) -> SimResult {
    let m = e.parallel.num_microbatches();
    let base = crate::schedule::one_f_one_b(e.parallel.p, m);
    let schedule = if e.bpipe {
        crate::bpipe::apply_bpipe(&base, None)
    } else {
        base
    };
    let layout = crate::bpipe::pair_adjacent_layout(e.parallel.p, e.cluster.n_nodes);
    simulate(e, &schedule, &layout)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{paper_experiment, paper_experiments};
    use crate::schedule::{gpipe, one_f_one_b};

    #[test]
    fn makespan_exceeds_critical_path_lower_bound() {
        let e = paper_experiment(7).unwrap();
        let r = simulate_experiment(&e);
        let cm = CostModel::new(&e);
        let st = cm.stage_times(1);
        let m = e.parallel.num_microbatches() as f64;
        // lower bound: one stage's serial work
        assert!(r.makespan >= m * st.total());
        // upper bound sanity: and not 3× it
        assert!(r.makespan < 3.0 * m * st.total());
    }

    #[test]
    fn mfu_in_sane_range_for_all_rows() {
        for e in paper_experiments() {
            let r = simulate_experiment(&e);
            assert!(
                r.mfu_pct() > 20.0 && r.mfu_pct() < 70.0,
                "exp {:?}: {:.1}%",
                e.id,
                r.mfu_pct()
            );
            assert!(r.oom_stage.is_none(), "exp {:?} must fit", e.id);
        }
    }

    #[test]
    fn gpipe_slower_than_1f1b_same_memory_model() {
        let e = paper_experiment(9).unwrap();
        let m = e.parallel.num_microbatches();
        let layout = crate::bpipe::pair_adjacent_layout(e.parallel.p, e.cluster.n_nodes);
        let g = simulate(&e, &gpipe(e.parallel.p, m), &layout);
        let f = simulate(&e, &one_f_one_b(e.parallel.p, m), &layout);
        // same bubble (flush at the end either way) but GPipe peaks at m stashes
        assert!(g.mem_high_water[0] > f.mem_high_water[0]);
        assert!((g.makespan - f.makespan) / f.makespan < 0.05);
    }

    #[test]
    fn bpipe_reduces_stage0_memory() {
        let mut e = paper_experiment(8).unwrap();
        let r_bpipe = simulate_experiment(&e);
        e.bpipe = false;
        let r_plain = simulate_experiment(&e);
        assert!(r_bpipe.mem_high_water[0] < r_plain.mem_high_water[0]);
        // plain 1F1B at b=2 OOMs on GPT-3 96B (why exp (8) needs BPipe)
        assert_eq!(r_plain.oom_stage, Some(0));
        assert!(r_bpipe.oom_stage.is_none());
    }

    #[test]
    fn bpipe_overhead_small_when_intra_node() {
        // BPipe at the same b must cost only a little (overlapped xfers)
        let mut e = paper_experiment(7).unwrap(); // b=1, fits without
        e.bpipe = true;
        let with = simulate_experiment(&e);
        e.bpipe = false;
        let without = simulate_experiment(&e);
        let overhead = with.makespan / without.makespan - 1.0;
        assert!(
            (0.0..0.08).contains(&overhead),
            "BPipe overhead {overhead:.3} out of range"
        );
    }

    #[test]
    fn memory_high_water_matches_analytical_model() {
        let e = paper_experiment(7).unwrap();
        let r = simulate_experiment(&e);
        let mm = MemoryModel::new(&e);
        for s in 0..e.parallel.p {
            let analytic = mm.peak_bytes_1f1b(s);
            let simulated = r.mem_high_water[s as usize];
            assert_eq!(simulated, analytic, "stage {s}");
        }
    }

    #[test]
    fn trace_is_complete_and_ordered() {
        let e = paper_experiment(7).unwrap();
        let r = simulate_experiment(&e);
        let m = e.parallel.num_microbatches() as usize;
        assert_eq!(
            r.trace.iter().filter(|t| t.kind == OpKind::Fwd).count(),
            m * e.parallel.p as usize
        );
        for w in r.trace.windows(2) {
            assert!(w[0].start <= w[1].start);
        }
    }

    #[test]
    fn load_stall_zero_when_no_bpipe() {
        let e = paper_experiment(7).unwrap();
        let r = simulate_experiment(&e);
        assert_eq!(r.load_stall, 0.0);
        assert_eq!(r.transfer_bytes, 0);
    }

    #[test]
    fn interleaved_cuts_bubble() {
        let e = paper_experiment(9).unwrap();
        let m = e.parallel.num_microbatches();
        let layout = crate::bpipe::pair_adjacent_layout(e.parallel.p, e.cluster.n_nodes);
        let plain = simulate(&e, &one_f_one_b(e.parallel.p, m), &layout);
        let il = simulate(&e, &crate::schedule::interleaved(e.parallel.p, m, 2), &layout);
        assert!(il.bubble_fraction < plain.bubble_fraction);
    }
}
