//! Discrete-event simulator: executes a [`Schedule`] against the
//! [`CostModel`](super::costmodel::CostModel) on a modeled cluster.
//!
//! Each stage has a FIFO **compute stream** (Fwd/Bwd) and each
//! evictor/acceptor pair a FIFO **transfer stream** (Evict/Load).  Ops
//! form a DAG:
//!
//! * `Fwd(s, i, c)` needs the previous hop of chunk `c`'s dataflow
//!   (`Fwd(s−1, i, c)` for sequential placement, the V path for
//!   [`Placement::VShape`]) and the previous compute op on stage `s`;
//! * `Bwd(s, i, c)` needs the downstream gradient along the reverse of
//!   that dataflow, its own `Fwd(s, i, c)`, the previous compute op, and
//!   — if the stash was evicted — the most recent `Load(s, i, c)`
//!   (rebalancing's only coupling into compute);
//! * `Evict/Load` need their triggering op and the previous transfer on
//!   the pair's link; a key may cycle Evict→Load repeatedly, so those
//!   deps are resolved by walking each program in order rather than by a
//!   unique per-key lookup.
//!
//! Completion times are computed by Kahn topological order; the engine
//! also tracks per-device stash residency over time (memory high-water,
//! OOM detection) and per-stream busy time (bubble fraction).
//!
//! ## Hot path
//!
//! All dependency lookups go through a **dense precomputed index**
//! (`stage × {Fwd,Bwd} × mb × chunk → node id`) instead of a `HashMap`,
//! and link arbitration state is a dense per-link array — this is the
//! inner loop of [`super::sweep`], which simulates the full
//! schedule × bound × layout × experiment grid (see
//! `benches/runtime_hotpath.rs`).

use super::costmodel::CostModel;
use crate::bpipe::{pairing, Layout};
use crate::config::ExperimentConfig;
use crate::model::{flops, memory::MemoryModel};
use crate::schedule::{OpKind, Placement, Schedule};

/// One executed op, for timeline rendering (paper Figure 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    pub stage: u64,
    pub kind: OpKind,
    pub mb: u64,
    pub chunk: u64,
    pub start: f64,
    pub end: f64,
}

/// Simulation output for one training iteration.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// iteration wall-clock (seconds)
    pub makespan: f64,
    /// whole-model MFU (0..1), paper Eq. MFU definition
    pub mfu: f64,
    /// per-stage compute busy time (seconds)
    pub busy: Vec<f64>,
    /// 1 − mean(busy)/makespan
    pub bubble_fraction: f64,
    /// per-stage peak device memory, bytes (weights+opt+stash+reserved)
    pub mem_high_water: Vec<u64>,
    /// per-stage peak resident stash count (own + accepted from partner)
    pub stash_high_water: Vec<i64>,
    /// stage that exceeded HBM capacity, if any
    pub oom_stage: Option<u64>,
    /// total backward stall time waiting on BPipe loads (seconds)
    pub load_stall: f64,
    /// total bytes moved by BPipe transfers
    pub transfer_bytes: u64,
    /// executed-op timeline
    pub trace: Vec<TraceEvent>,
}

impl SimResult {
    pub fn mfu_pct(&self) -> f64 {
        self.mfu * 100.0
    }
}

/// Export a trace as CSV (`stage,kind,mb,chunk,start,end`) for external
/// plotting — the machine-readable companion of the Figure-1 renderer.
pub fn trace_to_csv(trace: &[TraceEvent]) -> String {
    let mut out = String::from("stage,kind,mb,chunk,start,end\n");
    for ev in trace {
        out.push_str(&format!(
            "{},{:?},{},{},{:.9},{:.9}\n",
            ev.stage, ev.kind, ev.mb, ev.chunk, ev.start, ev.end
        ));
    }
    out
}

#[derive(Clone, Copy)]
struct Node {
    stage: usize,
    idx: usize,
}

const NONE: u32 = u32::MAX;

/// Dense `(stage, Fwd|Bwd, mb, chunk) → node id` index — the hot-path
/// replacement for the old per-op `HashMap` (compute ops are unique per
/// key by validation, so a flat array slot each suffices).
struct ComputeIndex {
    ids: Vec<u32>,
    m: usize,
    chunks: usize,
}

impl ComputeIndex {
    fn new(p: usize, m: usize, chunks: usize) -> Self {
        ComputeIndex { ids: vec![NONE; p * 2 * m * chunks], m, chunks }
    }

    #[inline]
    fn slot(&self, stage: usize, kind: OpKind, mb: u64, chunk: u64) -> usize {
        let k = match kind {
            OpKind::Fwd => 0,
            OpKind::Bwd => 1,
            _ => unreachable!("only compute ops are indexed"),
        };
        ((stage * 2 + k) * self.m + mb as usize) * self.chunks + chunk as usize
    }

    #[inline]
    fn set(&mut self, stage: usize, kind: OpKind, mb: u64, chunk: u64, id: u32) {
        let s = self.slot(stage, kind, mb, chunk);
        self.ids[s] = id;
    }

    /// Node id of a compute op that validation guarantees to exist.
    #[inline]
    fn get(&self, stage: usize, kind: OpKind, mb: u64, chunk: u64) -> usize {
        let id = self.ids[self.slot(stage, kind, mb, chunk)];
        debug_assert_ne!(id, NONE, "missing compute op in validated schedule");
        id as usize
    }
}

/// Simulate one iteration of `schedule` for experiment `e` on `layout`.
pub fn simulate(e: &ExperimentConfig, schedule: &Schedule, layout: &Layout) -> SimResult {
    crate::schedule::validate(schedule).expect("refusing to simulate an invalid schedule");
    let cm = CostModel::new(e);
    let mm = MemoryModel::new(e);
    let p = schedule.p as usize;
    let m = schedule.m as usize;
    let chunks = schedule.chunks.max(1) as usize;
    let vshape = schedule.placement == Placement::VShape;

    // -- global node ids ---------------------------------------------------
    let mut base = vec![0usize; p + 1];
    for s in 0..p {
        base[s + 1] = base[s] + schedule.programs[s].ops.len();
    }
    let n = base[p];
    let nodes: Vec<Node> = (0..p)
        .flat_map(|s| (0..schedule.programs[s].ops.len()).map(move |idx| Node { stage: s, idx }))
        .collect();

    // dense compute-op index (hot path: no hashing)
    let mut cix = ComputeIndex::new(p, m, chunks);
    for (id, nd) in nodes.iter().enumerate() {
        let op = schedule.programs[nd.stage].ops[nd.idx];
        if matches!(op.kind, OpKind::Fwd | OpKind::Bwd) {
            cix.set(nd.stage, op.kind, op.mb, op.chunk, id as u32);
        }
    }

    // previous virtual-pipeline hop of chunk `c`'s forward dataflow at
    // stage `s` (backward deps are the reverse of this path)
    let fwd_dep = |s: usize, mb: u64, chunk: u64| -> Option<usize> {
        if !vshape {
            if s > 0 {
                Some(cix.get(s - 1, OpKind::Fwd, mb, chunk))
            } else if chunk > 0 {
                // interleaved wrap: chunk c at stage 0 consumes
                // chunk c−1 at stage p−1
                Some(cix.get(p - 1, OpKind::Fwd, mb, chunk - 1))
            } else {
                None
            }
        } else if chunk == 0 {
            if s > 0 { Some(cix.get(s - 1, OpKind::Fwd, mb, 0)) } else { None }
        } else if s == p - 1 {
            // V junction: chunk 1 starts where chunk 0 ends
            Some(cix.get(p - 1, OpKind::Fwd, mb, 0))
        } else {
            // chunk 1 flows p−1 → 0
            Some(cix.get(s + 1, OpKind::Fwd, mb, 1))
        }
    };
    let bwd_dep = |s: usize, mb: u64, chunk: u64| -> Option<usize> {
        if !vshape {
            if s + 1 < p {
                Some(cix.get(s + 1, OpKind::Bwd, mb, chunk))
            } else if chunk + 1 < chunks as u64 {
                // interleaved wrap: grad for chunk c at stage p−1
                // comes from chunk c+1 at stage 0
                Some(cix.get(0, OpKind::Bwd, mb, chunk + 1))
            } else {
                None
            }
        } else if chunk == 1 {
            if s > 0 { Some(cix.get(s - 1, OpKind::Bwd, mb, 1)) } else { None }
        } else if s + 1 < p {
            Some(cix.get(s + 1, OpKind::Bwd, mb, 0))
        } else {
            // V junction in reverse: chunk 0's grad at stage p−1 comes
            // from chunk 1 at stage p−1
            Some(cix.get(p - 1, OpKind::Bwd, mb, 1))
        }
    };

    // -- dependency edges ---------------------------------------------------
    // Evict/Load deps are walk-local: a key may be evicted and reloaded
    // repeatedly, so each Load binds to the most recent Evict of its key
    // and each Bwd to the most recent Load (dense per-key scratch, reset
    // per stage).
    let mut deps: Vec<Vec<usize>> = vec![Vec::with_capacity(3); n];
    let mut bwd_load_dep: Vec<u32> = vec![NONE; n];
    let mut prev_compute: Option<usize>;
    let key_count = m * chunks;
    let mut last_evict = vec![NONE; key_count];
    let mut last_load = vec![NONE; key_count];
    for s in 0..p {
        prev_compute = None;
        last_evict.fill(NONE);
        last_load.fill(NONE);
        for (idx, op) in schedule.programs[s].ops.iter().enumerate() {
            let id = base[s] + idx;
            let key = op.mb as usize * chunks + op.chunk as usize;
            match op.kind {
                OpKind::Fwd => {
                    if let Some(prev) = prev_compute {
                        deps[id].push(prev);
                    }
                    if let Some(d) = fwd_dep(s, op.mb, op.chunk) {
                        deps[id].push(d);
                    }
                    prev_compute = Some(id);
                }
                OpKind::Bwd => {
                    if let Some(prev) = prev_compute {
                        deps[id].push(prev);
                    }
                    deps[id].push(cix.get(s, OpKind::Fwd, op.mb, op.chunk));
                    if let Some(d) = bwd_dep(s, op.mb, op.chunk) {
                        deps[id].push(d);
                    }
                    if last_load[key] != NONE {
                        deps[id].push(last_load[key] as usize);
                        bwd_load_dep[id] = last_load[key];
                    }
                    prev_compute = Some(id);
                }
                OpKind::Evict | OpKind::Load => {
                    // issue point: the op preceding it in program order
                    if idx > 0 {
                        deps[id].push(base[s] + idx - 1);
                    }
                    if op.kind == OpKind::Load {
                        deps[id].push(last_evict[key] as usize);
                        last_load[key] = id as u32;
                    } else {
                        last_evict[key] = id as u32;
                        last_load[key] = NONE;
                    }
                    // link arbitration is time-based (FCFS per link) in
                    // the event loop below, not a static dependency —
                    // static chaining of a *shared* uplink across stages
                    // can create artificial cycles.
                }
            }
        }
    }

    // -- durations ----------------------------------------------------------
    let stage_times: Vec<_> = (0..p).map(|s| cm.stage_times(s as u64)).collect();
    // interleaved/V chunks split a stage's layers `chunks` ways
    let chunk_scale = 1.0 / chunks as f64;
    let dur = |nd: &Node| -> f64 {
        let op = schedule.programs[nd.stage].ops[nd.idx];
        match op.kind {
            OpKind::Fwd => stage_times[nd.stage].fwd * chunk_scale,
            OpKind::Bwd => stage_times[nd.stage].bwd * chunk_scale,
            OpKind::Evict | OpKind::Load => {
                let intra = layout.pair_intra_node(p as u64, nd.stage as u64);
                cm.transfer_time_chunked(intra, chunks as u64)
            }
        }
    };

    // -- event-driven timing with FCFS link arbitration ----------------------
    // Ops become READY when all logical deps complete; compute ops start
    // at their ready time (program-order deps already serialize the
    // stage's compute stream); transfer ops additionally queue FCFS on
    // their link.  Events are processed in ready-time order, which makes
    // the link free-time bookkeeping causally consistent.
    let mut indeg = vec![0usize; n];
    let mut rev: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (id, ds) in deps.iter().enumerate() {
        indeg[id] = ds.len();
        for &d in ds {
            rev[d].push(id);
        }
    }
    let mut start = vec![0f64; n];
    let mut end = vec![0f64; n];
    // BinaryHeap over (ready_time, id); f64 wrapped for total order
    #[derive(PartialEq)]
    struct Ev(f64, usize);
    impl Eq for Ev {}
    impl PartialOrd for Ev {
        fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(other))
        }
    }
    impl Ord for Ev {
        fn cmp(&self, other: &Self) -> std::cmp::Ordering {
            // min-heap: reverse on time, tie-break on id for determinism
            other
                .0
                .partial_cmp(&self.0)
                .unwrap()
                .then(other.1.cmp(&self.1))
        }
    }
    let mut heap: std::collections::BinaryHeap<Ev> = (0..n)
        .filter(|&i| indeg[i] == 0)
        .map(|i| Ev(0.0, i))
        .collect();
    // dense per-link free-time: nvlink pair k < p, then IB uplink per node
    let n_nodes = layout.n_nodes as usize;
    let mut link_free = vec![0f64; p + n_nodes];
    let link_of = |stage: usize| -> usize {
        if layout.pair_intra_node(p as u64, stage as u64) {
            stage.min(p - 1 - stage)
        } else {
            p + layout.node_of(stage as u64) as usize
        }
    };
    let mut done = 0usize;
    let mut load_stall = 0f64;
    while let Some(Ev(ready, id)) = heap.pop() {
        done += 1;
        let nd = nodes[id];
        let op = schedule.programs[nd.stage].ops[nd.idx];
        let t0 = match op.kind {
            OpKind::Evict | OpKind::Load => {
                let free = &mut link_free[link_of(nd.stage)];
                let s = ready.max(*free);
                *free = s + dur(&nd);
                s
            }
            _ => ready,
        };
        start[id] = t0;
        end[id] = t0 + dur(&nd);
        if op.kind == OpKind::Bwd && bwd_load_dep[id] != NONE {
            let load = bwd_load_dep[id] as usize;
            let without: f64 = deps[id]
                .iter()
                .filter(|&&d| d != load)
                .map(|&d| end[d])
                .fold(0f64, f64::max);
            load_stall += (end[load] - without).max(0.0);
        }
        for &nxt in &rev[id] {
            indeg[nxt] -= 1;
            if indeg[nxt] == 0 {
                let r = deps[nxt].iter().map(|&d| end[d]).fold(0f64, f64::max);
                heap.push(Ev(r, nxt));
            }
        }
    }
    assert_eq!(done, n, "dependency cycle in schedule DAG");

    // -- aggregate ------------------------------------------------------------
    let makespan = end.iter().cloned().fold(0f64, f64::max);
    let mut busy = vec![0f64; p];
    let mut trace = Vec::with_capacity(n);
    for (id, nd) in nodes.iter().enumerate() {
        let op = schedule.programs[nd.stage].ops[nd.idx];
        if matches!(op.kind, OpKind::Fwd | OpKind::Bwd) {
            busy[nd.stage] += end[id] - start[id];
        }
        trace.push(TraceEvent {
            stage: nd.stage as u64,
            kind: op.kind,
            mb: op.mb,
            chunk: op.chunk,
            start: start[id],
            end: end[id],
        });
    }
    trace.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());

    // -- memory timeline -------------------------------------------------------
    // events: (time, stage, delta_stashes); a stash of a chunked schedule
    // holds only 1/chunks of the stage's layers, so stash (and transfer)
    // bytes scale by the chunk count
    let act = mm.activation_bytes_per_microbatch(0) / chunks as u64;
    let mut events: Vec<(f64, usize, i64)> = Vec::new();
    for (id, nd) in nodes.iter().enumerate() {
        let op = schedule.programs[nd.stage].ops[nd.idx];
        let partner = pairing::partner(p as u64, nd.stage as u64) as usize;
        match op.kind {
            OpKind::Fwd => events.push((end[id], nd.stage, 1)),
            OpKind::Bwd => events.push((end[id], nd.stage, -1)),
            OpKind::Evict => {
                // freed locally only once the transfer lands; acceptor
                // allocates at transfer start (conservative overlap)
                events.push((end[id], nd.stage, -1));
                events.push((start[id], partner, 1));
            }
            OpKind::Load => {
                events.push((start[id], nd.stage, 1));
                events.push((end[id], partner, -1));
            }
        }
    }
    events.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.2.cmp(&b.2)));
    let mut cur = vec![0i64; p];
    let mut hw = vec![0i64; p];
    for (_, s, d) in events {
        cur[s] += d;
        hw[s] = hw[s].max(cur[s]);
    }
    let mem_high_water: Vec<u64> = (0..p)
        .map(|s| {
            mm.weight_opt_bytes(s as u64) + e.cluster.reserved_bytes + hw[s] as u64 * act
        })
        .collect();
    let oom_stage = mem_high_water
        .iter()
        .position(|&b| b > e.cluster.hbm_bytes)
        .map(|s| s as u64);

    let transfers = schedule
        .programs
        .iter()
        .flat_map(|pr| pr.ops.iter())
        .filter(|o| matches!(o.kind, OpKind::Evict | OpKind::Load))
        .count() as u64;

    let model_flops = flops::model_flops_per_iteration(&e.model, e.parallel.global_batch);
    let devices = e.parallel.devices() as f64;
    let mfu = model_flops / (devices * e.cluster.peak_flops * makespan);
    let mean_busy: f64 = busy.iter().sum::<f64>() / p as f64;

    SimResult {
        makespan,
        mfu,
        bubble_fraction: 1.0 - mean_busy / makespan,
        busy,
        mem_high_water,
        stash_high_water: hw,
        oom_stage,
        load_stall,
        transfer_bytes: transfers * act,
        trace,
    }
}

/// Build the schedule an experiment config implies (1F1B, +BPipe if
/// enabled) with the pair-adjacent layout, simulate one iteration.
pub fn simulate_experiment(e: &ExperimentConfig) -> SimResult {
    let m = e.parallel.num_microbatches();
    let base = crate::schedule::one_f_one_b(e.parallel.p, m);
    let schedule = if e.bpipe {
        crate::bpipe::apply_bpipe(&base, None)
    } else {
        base
    };
    let layout = crate::bpipe::pair_adjacent_layout(e.parallel.p, e.cluster.n_nodes);
    simulate(e, &schedule, &layout)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bpipe::{derived_bound, rebalance};
    use crate::config::{paper_experiment, paper_experiments};
    use crate::schedule::{gpipe, interleaved, one_f_one_b, v_shaped};

    #[test]
    fn makespan_exceeds_critical_path_lower_bound() {
        let e = paper_experiment(7).unwrap();
        let r = simulate_experiment(&e);
        let cm = CostModel::new(&e);
        let st = cm.stage_times(1);
        let m = e.parallel.num_microbatches() as f64;
        // lower bound: one stage's serial work
        assert!(r.makespan >= m * st.total());
        // upper bound sanity: and not 3× it
        assert!(r.makespan < 3.0 * m * st.total());
    }

    #[test]
    fn mfu_in_sane_range_for_all_rows() {
        for e in paper_experiments() {
            let r = simulate_experiment(&e);
            assert!(
                r.mfu_pct() > 20.0 && r.mfu_pct() < 70.0,
                "exp {:?}: {:.1}%",
                e.id,
                r.mfu_pct()
            );
            assert!(r.oom_stage.is_none(), "exp {:?} must fit", e.id);
        }
    }

    #[test]
    fn gpipe_slower_than_1f1b_same_memory_model() {
        let e = paper_experiment(9).unwrap();
        let m = e.parallel.num_microbatches();
        let layout = crate::bpipe::pair_adjacent_layout(e.parallel.p, e.cluster.n_nodes);
        let g = simulate(&e, &gpipe(e.parallel.p, m), &layout);
        let f = simulate(&e, &one_f_one_b(e.parallel.p, m), &layout);
        // same bubble (flush at the end either way) but GPipe peaks at m stashes
        assert!(g.mem_high_water[0] > f.mem_high_water[0]);
        assert!((g.makespan - f.makespan) / f.makespan < 0.05);
    }

    #[test]
    fn bpipe_reduces_stage0_memory() {
        let mut e = paper_experiment(8).unwrap();
        let r_bpipe = simulate_experiment(&e);
        e.bpipe = false;
        let r_plain = simulate_experiment(&e);
        assert!(r_bpipe.mem_high_water[0] < r_plain.mem_high_water[0]);
        // plain 1F1B at b=2 OOMs on GPT-3 96B (why exp (8) needs BPipe)
        assert_eq!(r_plain.oom_stage, Some(0));
        assert!(r_bpipe.oom_stage.is_none());
    }

    #[test]
    fn bpipe_overhead_small_when_intra_node() {
        // BPipe at the same b must cost only a little (overlapped xfers)
        let mut e = paper_experiment(7).unwrap(); // b=1, fits without
        e.bpipe = true;
        let with = simulate_experiment(&e);
        e.bpipe = false;
        let without = simulate_experiment(&e);
        let overhead = with.makespan / without.makespan - 1.0;
        assert!(
            (0.0..0.08).contains(&overhead),
            "BPipe overhead {overhead:.3} out of range"
        );
    }

    #[test]
    fn memory_high_water_matches_analytical_model() {
        let e = paper_experiment(7).unwrap();
        let r = simulate_experiment(&e);
        let mm = MemoryModel::new(&e);
        for s in 0..e.parallel.p {
            let analytic = mm.peak_bytes_1f1b(s);
            let simulated = r.mem_high_water[s as usize];
            assert_eq!(simulated, analytic, "stage {s}");
        }
    }

    #[test]
    fn trace_is_complete_and_ordered() {
        let e = paper_experiment(7).unwrap();
        let r = simulate_experiment(&e);
        let m = e.parallel.num_microbatches() as usize;
        assert_eq!(
            r.trace.iter().filter(|t| t.kind == OpKind::Fwd).count(),
            m * e.parallel.p as usize
        );
        for w in r.trace.windows(2) {
            assert!(w[0].start <= w[1].start);
        }
    }

    #[test]
    fn load_stall_zero_when_no_bpipe() {
        let e = paper_experiment(7).unwrap();
        let r = simulate_experiment(&e);
        assert_eq!(r.load_stall, 0.0);
        assert_eq!(r.transfer_bytes, 0);
    }

    #[test]
    fn interleaved_cuts_bubble() {
        let e = paper_experiment(9).unwrap();
        let m = e.parallel.num_microbatches();
        let layout = crate::bpipe::pair_adjacent_layout(e.parallel.p, e.cluster.n_nodes);
        let plain = simulate(&e, &one_f_one_b(e.parallel.p, m), &layout);
        let il = simulate(&e, &crate::schedule::interleaved(e.parallel.p, m, 2), &layout);
        assert!(il.bubble_fraction < plain.bubble_fraction);
    }

    #[test]
    fn rebalanced_interleaved_flattens_memory() {
        // the tentpole end-to-end: rebalance(interleaved) simulates, and
        // the derived bound flattens the 23..9 stash ramp to a uniform
        // pair mean (16 per stage for p=8, m=64, v=2)
        let e = paper_experiment(8).unwrap();
        let m = e.parallel.num_microbatches();
        let layout = crate::bpipe::pair_adjacent_layout(e.parallel.p, e.cluster.n_nodes);
        let il = interleaved(e.parallel.p, m, 2);
        let plain = simulate(&e, &il, &layout);
        let rb = rebalance(&il, None);
        let r = simulate(&e, &rb, &layout);
        let spread = |hw: &[i64]| hw.iter().max().unwrap() - hw.iter().min().unwrap();
        assert!(
            spread(&r.stash_high_water) < spread(&plain.stash_high_water),
            "{:?} vs {:?}",
            r.stash_high_water,
            plain.stash_high_water
        );
        let peak = |v: &[u64]| *v.iter().max().unwrap();
        assert!(peak(&r.mem_high_water) < peak(&plain.mem_high_water));
        // transfers hide under compute on the pair-adjacent layout
        assert!(r.makespan / plain.makespan < 1.05);
    }

    #[test]
    fn chunked_stash_bytes_scale_with_chunk_count() {
        // satellite fix: a v-chunk stash pins 1/v of a stage's layers —
        // the interleaved timeline must account act/v per stash
        let e = paper_experiment(9).unwrap();
        let m = e.parallel.num_microbatches();
        let layout = crate::bpipe::pair_adjacent_layout(e.parallel.p, e.cluster.n_nodes);
        let r = simulate(&e, &interleaved(e.parallel.p, m, 2), &layout);
        let mm = MemoryModel::new(&e);
        let act = mm.activation_bytes_per_microbatch(0);
        for s in 0..e.parallel.p as usize {
            let stash_bytes =
                r.mem_high_water[s] - mm.weight_opt_bytes(s as u64) - e.cluster.reserved_bytes;
            assert_eq!(stash_bytes, r.stash_high_water[s] as u64 * (act / 2), "stage {s}");
        }
    }

    #[test]
    fn v_shaped_simulates_with_balanced_stashes() {
        let e = paper_experiment(8).unwrap();
        let m = e.parallel.num_microbatches();
        let layout = crate::bpipe::pair_adjacent_layout(e.parallel.p, e.cluster.n_nodes);
        let r = simulate(&e, &v_shaped(e.parallel.p, m), &layout);
        assert!(r.makespan > 0.0 && r.mfu > 0.0);
        let spread = r.stash_high_water.iter().max().unwrap()
            - r.stash_high_water.iter().min().unwrap();
        assert!(spread <= 1, "V-shaped per-device stash {:?}", r.stash_high_water);
    }

    #[test]
    fn rebalance_composes_with_v_shaped_in_sim() {
        let e = paper_experiment(8).unwrap();
        let m = e.parallel.num_microbatches();
        let layout = crate::bpipe::pair_adjacent_layout(e.parallel.p, e.cluster.n_nodes);
        let base = v_shaped(e.parallel.p, m);
        let bound = derived_bound(&base);
        let r = simulate(&e, &rebalance(&base, Some(bound)), &layout);
        assert!(r.makespan > 0.0, "rebalanced V-shaped must execute");
    }
}
