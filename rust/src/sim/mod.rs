//! Discrete-event cluster simulator (substrate S1 of DESIGN.md).
//!
//! [`costmodel`] turns (model, parallelism, attention method) into per-op
//! wall-clock times on a modeled A100; [`engine`] executes pipeline
//! schedules against those times in a reusable zero-allocation
//! [`SimWorkspace`] (flat CSR dependency edges, dense op index, opt-in
//! trace), tracking memory, bubbles, BPipe transfer overlap and MFU;
//! [`sweep()`] fans the full schedule × bound × layout × experiment grid
//! out over a thread pool — one workspace per worker — ranks the
//! outcomes, and exports them as CSV/JSON.  Together they regenerate the
//! paper's Tables 3/5 and Figures 1/2 at the paper's scale on one CPU —
//! and answer the generalized questions the paper stops short of:
//! *which* schedule family wins once rebalancing composes with all of
//! them, and *how low can the bound go* before load stalls or acceptor
//! overflow take the win back (the bound × load_stall frontier).

pub mod costmodel;
pub mod engine;
pub mod sweep;

pub use costmodel::{CostModel, SoftmaxKernel, StageTimes};
pub use engine::{
    simulate, simulate_experiment, SimOptions, SimResult, SimStats, SimWorkspace, TraceEvent,
};
pub use sweep::{
    bound_sensitivity_tasks, bounds_grid, experiment_tasks, frontier_outcomes, paper_grid,
    render_bound_frontier, render_sweep, scenario_specs, sweep, sweep_to_csv, sweep_to_json,
    sweep_with, ScenarioSpec, ScheduleCache, SweepOptions, SweepOutcome, SweepReport, SweepTask,
};
