//! Discrete-event cluster simulator (substrate S1 of DESIGN.md).
//!
//! [`costmodel`] turns (model, parallelism, attention method) into per-op
//! wall-clock times on a modeled A100; [`engine`] executes pipeline
//! schedules against those times, tracking memory, bubbles, BPipe
//! transfer overlap and MFU; [`sweep`] fans the full
//! schedule × bound × layout × experiment grid out over a thread pool
//! and ranks the outcomes.  Together they regenerate the paper's
//! Tables 3/5 and Figures 1/2 at the paper's scale on one CPU — and
//! answer the generalized question the paper stops short of: *which*
//! schedule family wins once rebalancing composes with all of them.

pub mod costmodel;
pub mod engine;
pub mod sweep;

pub use costmodel::{CostModel, SoftmaxKernel, StageTimes};
pub use engine::{simulate, simulate_experiment, SimResult, TraceEvent};
pub use sweep::{
    experiment_tasks, paper_grid, render_sweep, scenarios, sweep, SweepOutcome, SweepTask,
};
