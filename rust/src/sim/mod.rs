//! Discrete-event cluster simulator (substrate S1 of DESIGN.md).
//!
//! [`costmodel`] turns (model, parallelism, attention method) into per-op
//! wall-clock times on a modeled A100; [`engine`] executes pipeline
//! schedules against those times, tracking memory, bubbles, BPipe
//! transfer overlap and MFU.  Together they regenerate the paper's
//! Tables 3/5 and Figures 1/2 at the paper's scale on one CPU.

pub mod costmodel;
pub mod engine;

pub use costmodel::{CostModel, SoftmaxKernel, StageTimes};
pub use engine::{simulate, simulate_experiment, SimResult, TraceEvent};
