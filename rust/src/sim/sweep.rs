//! `sim::sweep` — the parallel design-space sweep driver.
//!
//! The paper answers "does memory rebalancing pay off?" for exactly one
//! schedule (1F1B).  With [`crate::bpipe::rebalance()`] schedule-agnostic,
//! the interesting spaces are two grids:
//!
//! ```text
//! experiment (Table 3 rows) × schedule scenario × device layout
//! experiment × rebalanceable family × bound (derived → 2) × layout
//! ```
//!
//! The first ranks the scheduling families — imbalanced (1F1B, GPipe),
//! anti-balanced virtual pipelines (interleaved), balanced-by-placement
//! (V-shaped, and W-shaped = zig-zag at four chunks) — each bare,
//! rebalanced at its derived uniform bound, and rebalanced at the
//! capacity-derived **per-stage bounds** ([`ScenarioSpec::stage_bounded`],
//! the SlimPipe-motivated non-uniform variant).  The second
//! ([`bounds_grid`], `bpipe sweep --bounds`) traces the **bound ×
//! load_stall sensitivity frontier**: for every scenario, rebalance at
//! every uniform bound from the derived value down to the infeasibility
//! knee, showing where tighter memory starts costing stalls (and where
//! the acceptor side OOMs) — ~7200 cells at paper scale over four
//! layouts (pair-adjacent, sequential, scatter, ring), ~24× the
//! ranking grid.  Bound cells are ordered bound-descending within each
//! (family, layout) run so the warm-start DES replay in
//! [`SimWorkspace`] can reuse the shared event prefix between adjacent
//! bounds; [`SweepReport`] carries the replay telemetry.
//!
//! ## Execution model
//!
//! A [`SweepTask`] is **lazy**: it carries a tiny [`ScenarioSpec`]
//! (family + optional bound), not a materialized `Schedule` clone — the
//! worker thread generates the schedule per cell.  [`sweep`] fans tasks
//! out over scoped OS threads (the build is offline, so no rayon; a
//! shared atomic index gives the same work-stealing shape).  Each worker
//! owns one reusable [`SimWorkspace`], so steady-state cells run the DES
//! with **zero heap allocation**; results land in indexed `OnceLock`
//! slots (no `Mutex<Vec>` push, no reordering pass).
//!
//! [`render_sweep`] emits one ranked table (feasible cells by MFU, OOM
//! cells flagged at the bottom); [`render_bound_frontier`] condenses the
//! bounds grid per scenario; [`sweep_to_csv`] / [`sweep_to_json`] export
//! every cell for external plotting (`--csv` / `--json`).

use super::costmodel::CostModel;
use super::engine::{SimOptions, SimWorkspace};
use crate::bpipe::{
    bound_range, pair_adjacent_layout, ring_layout, scatter_layout, sequential_layout, Layout,
};
use crate::config::{paper_experiments, ExperimentConfig};
use crate::report::Table;
use crate::schedule::{Family, Schedule, ScheduleKind};
use crate::util::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};

/// What to run in one cell, before the schedule exists: a generator
/// family, optionally composed with the rebalance transform at a fixed,
/// derived, or per-stage capacity-derived bound.  `Copy`-small on
/// purpose — the grid holds thousands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScenarioSpec {
    pub family: Family,
    /// compose with [`crate::bpipe::rebalance()`]?
    pub rebalance: bool,
    /// explicit rebalance bound; `None` = the derived pair-mean bound
    pub bound: Option<u64>,
    /// compose with [`crate::bpipe::rebalance_bounded`] at the
    /// capacity-derived per-stage bounds instead of a uniform one
    /// ([`crate::bpipe::capacity_stage_bounds`]; needs the experiment, so
    /// only [`ScenarioSpec::build_for`] can materialize it)
    pub per_stage: bool,
}

impl ScenarioSpec {
    /// The family alone.
    pub fn base(family: Family) -> Self {
        ScenarioSpec { family, rebalance: false, bound: None, per_stage: false }
    }

    /// The family composed with rebalancing (derived bound if `None`).
    pub fn rebalanced(family: Family, bound: Option<u64>) -> Self {
        ScenarioSpec { family, rebalance: true, bound, per_stage: false }
    }

    /// The family composed with per-stage capacity-derived rebalancing.
    pub fn stage_bounded(family: Family) -> Self {
        ScenarioSpec { family, rebalance: true, bound: None, per_stage: true }
    }

    /// Display name ("1F1B", "1F1B+rebalance", "1F1B+stage-bounds", …) —
    /// derived so it can never desync from the flags it labels.
    pub fn name(&self) -> &'static str {
        if self.per_stage {
            self.family.stage_bounds_label()
        } else if self.rebalance {
            self.family.rebalanced_label()
        } else {
            self.family.label()
        }
    }

    /// Materialize the schedule this spec describes, independent of any
    /// experiment.  Per-stage specs need the experiment's memory model —
    /// use [`ScenarioSpec::build_for`] for those.
    pub fn build(&self, p: u64, m: u64) -> Schedule {
        assert!(
            !self.per_stage,
            "per-stage bounds are capacity-derived: build_for(experiment) required"
        );
        let base = self.family.build(p, m);
        if self.rebalance {
            crate::bpipe::rebalance(&base, self.bound)
        } else {
            base
        }
    }

    /// Materialize the schedule this spec describes for one experiment
    /// (shape from its parallelism; per-stage bounds from its memory
    /// model).
    pub fn build_for(&self, e: &ExperimentConfig) -> Schedule {
        let p = e.parallel.p;
        let m = e.parallel.num_microbatches();
        if self.per_stage {
            let base = self.family.build(p, m);
            let bounds = crate::bpipe::capacity_stage_bounds(e, &base);
            crate::bpipe::rebalance_bounded(&base, &bounds)
        } else {
            self.build(p, m)
        }
    }
}

/// Per-worker schedule construction cache: the last base schedule built
/// (keyed by family × p × m) plus a reusable
/// [`crate::bpipe::RebalanceWorkspace`].  The bound-sensitivity grid
/// lists one experiment's cells family-by-family, bound-by-bound, so
/// consecutive cells on a worker almost always share their base — and
/// the base build (the zigzag generator's virtual list-schedule in
/// particular) dominates cell setup.  A cache hit turns that into one
/// clone (base cells) or one scratch-reusing rebalance pass.
pub struct ScheduleCache {
    base: Option<(Family, u64, u64, Schedule)>,
    rb: crate::bpipe::RebalanceWorkspace,
}

impl Default for ScheduleCache {
    fn default() -> Self {
        Self::new()
    }
}

impl ScheduleCache {
    pub fn new() -> Self {
        Self { base: None, rb: crate::bpipe::RebalanceWorkspace::new() }
    }

    /// [`ScenarioSpec::build_for`], with the base schedule cached across
    /// calls — identical output, cheaper steady state.
    pub fn build_for(&mut self, spec: &ScenarioSpec, e: &ExperimentConfig) -> Schedule {
        let p = e.parallel.p;
        let m = e.parallel.num_microbatches();
        let hit = matches!(
            &self.base,
            Some((f, bp, bm, _)) if *f == spec.family && *bp == p && *bm == m
        );
        if !hit {
            self.base = Some((spec.family, p, m, spec.family.build(p, m)));
        }
        let (_, _, _, base) = self.base.as_ref().unwrap();
        if spec.per_stage {
            let bounds = crate::bpipe::capacity_stage_bounds(e, base);
            self.rb.rebalance_bounded(base, &bounds)
        } else if spec.rebalance {
            self.rb.rebalance(base, spec.bound)
        } else {
            base.clone()
        }
    }
}

/// One cell of the sweep grid, before simulation.  The experiment config
/// is shared (`Arc`) across all of one experiment's cells — with ~2.3k
/// bounds-grid tasks, per-task deep clones would dominate grid
/// construction.
pub struct SweepTask {
    pub experiment: Arc<ExperimentConfig>,
    pub spec: ScenarioSpec,
    pub layout: Layout,
}

/// One simulated cell of the grid.
#[derive(Debug, Clone)]
pub struct SweepOutcome {
    pub exp_id: Option<u32>,
    pub model: String,
    pub microbatch: u64,
    pub scenario: &'static str,
    /// the uniform rebalance bound actually applied (derived or
    /// explicit), if any — `None` for base and per-stage-bounds cells
    pub bound: Option<u64>,
    /// the per-stage bounds actually applied (capacity-derived), if any
    pub stage_bounds: Option<Vec<u64>>,
    pub layout: &'static str,
    pub mfu_pct: f64,
    pub makespan: f64,
    pub bubble_pct: f64,
    pub peak_mem_gib: f64,
    /// per-stage peak device memory (GiB) — Figure-1 renderer input
    pub per_stage_mem_gib: Vec<f64>,
    pub oom_stage: Option<u64>,
    pub load_stall_ms: f64,
    pub transfer_gib: f64,
}

/// The fifteen schedule scenarios of the ranking grid: five scheduling
/// families — imbalanced (1F1B), memory-worst-case (GPipe),
/// anti-balanced virtual pipeline (interleaved), balanced-by-placement
/// (V-shaped, W-shaped = zig-zag v=4) — each bare, rebalanced at the
/// derived uniform bound, and rebalanced at the capacity-derived
/// per-stage bounds.
pub fn scenario_specs(v: u64) -> Vec<ScenarioSpec> {
    let families = [
        Family::OneFOneB,
        Family::GPipe,
        Family::Interleaved { v },
        Family::VShaped,
        Family::ZigZag { v: 4 },
    ];
    families
        .iter()
        .flat_map(|&f| {
            [
                ScenarioSpec::base(f),
                ScenarioSpec::rebalanced(f, None),
                ScenarioSpec::stage_bounded(f),
            ]
        })
        .collect()
}

/// All ranking-grid tasks for one experiment: every scenario × the
/// {pair-adjacent, sequential} layouts — the one place the grid's inner
/// dimensions are defined (paper_grid, the CLI and the tests all build
/// on it).
pub fn experiment_tasks(e: &ExperimentConfig, v: u64) -> Vec<SweepTask> {
    let p = e.parallel.p;
    let shared = Arc::new(e.clone());
    let mut tasks = Vec::new();
    for spec in scenario_specs(v) {
        for layout in [
            pair_adjacent_layout(p, e.cluster.n_nodes),
            sequential_layout(p, e.cluster.n_nodes),
        ] {
            tasks.push(SweepTask { experiment: Arc::clone(&shared), spec, layout });
        }
    }
    tasks
}

/// Build the full ranking grid: every Table-3 experiment × every
/// scenario × {pair-adjacent, sequential} layout.
pub fn paper_grid(v: u64) -> Vec<SweepTask> {
    paper_experiments().iter().flat_map(|e| experiment_tasks(e, v)).collect()
}

/// Bound-sensitivity tasks for one experiment: every rebalanceable
/// family (1F1B, GPipe, interleaved, V-shaped, W-shaped) at **every**
/// bound from its derived pair-mean value down to the infeasibility
/// knee (2, the smallest the transform admits: one live + one incoming
/// stash), on all four layouts (pair-adjacent, sequential, scatter,
/// ring).  Sweeping the whole range — instead of the single derived
/// point — exposes the memory/throughput frontier: `load_stall` grows
/// and the acceptor side eventually OOMs as the bound tightens.
///
/// Task order is family → layout → bound **descending**: consecutive
/// cells on a worker then share family, shape and layout and differ
/// only by one bound step, which is exactly the adjacency the
/// warm-start DES replay ([`SimWorkspace`] snapshot) exploits — the
/// cell at bound `b` replays the event prefix shared with `b+1`.
pub fn bound_sensitivity_tasks(e: &ExperimentConfig, v: u64) -> Vec<SweepTask> {
    let p = e.parallel.p;
    let m = e.parallel.num_microbatches();
    let n_nodes = e.cluster.n_nodes;
    let shared = Arc::new(e.clone());
    let mut tasks = Vec::new();
    for family in [
        Family::OneFOneB,
        Family::GPipe,
        Family::Interleaved { v },
        Family::VShaped,
        Family::ZigZag { v: 4 },
    ] {
        let base = family.build(p, m);
        for layout in [
            pair_adjacent_layout(p, n_nodes),
            sequential_layout(p, n_nodes),
            scatter_layout(p, n_nodes),
            ring_layout(p, n_nodes),
        ] {
            for bound in bound_range(&base).rev() {
                let spec = ScenarioSpec::rebalanced(family, Some(bound));
                tasks.push(SweepTask {
                    experiment: Arc::clone(&shared),
                    spec,
                    layout: layout.clone(),
                });
            }
        }
    }
    tasks
}

/// The full bound-sensitivity grid over every Table-3 experiment
/// (~7200 cells at paper scale over four layouts; `bpipe sweep --bounds`).
pub fn bounds_grid(v: u64) -> Vec<SweepTask> {
    paper_experiments().iter().flat_map(|e| bound_sensitivity_tasks(e, v)).collect()
}

/// The **found-vs-family frontier** under tight HBM: clone `e` with the
/// per-device HBM capped at 90% (`hbm_bytes / 10 * 9` — tight enough
/// that at paper scale no hand-written family fits exp (8)), run every
/// ranking-grid scenario on the pair-adjacent layout through the
/// provable-OOM skip gate, then add one `"synthesized"` cell:
/// [`crate::schedule::synthesize`] searched under uniform per-stage
/// byte caps equal to the tightened HBM.  Returns the cap (bytes) and
/// the outcomes — family cells keep the grid's shape with `oom_stage`
/// flagged, and the synthesized cell reports its stash budgets through
/// the `stage_bounds` column (`bpipe sweep --synth`, the report's
/// frontier panel, and the CI frontier-CSV artifact all read this).
pub fn frontier_outcomes(
    e: &ExperimentConfig,
    v: u64,
    threads: usize,
) -> (u64, Vec<SweepOutcome>) {
    let gib = (1u64 << 30) as f64;
    let cap = e.cluster.hbm_bytes / 10 * 9;
    let mut tight = e.clone();
    tight.cluster.hbm_bytes = cap;
    let p = tight.parallel.p;
    let m = tight.parallel.num_microbatches();
    let layout = pair_adjacent_layout(p, tight.cluster.n_nodes);
    let shared = Arc::new(tight.clone());
    let tasks: Vec<SweepTask> = scenario_specs(v)
        .into_iter()
        .map(|spec| SweepTask {
            experiment: Arc::clone(&shared),
            spec,
            layout: pair_adjacent_layout(p, tight.cluster.n_nodes),
        })
        .collect();
    let mut outcomes = sweep_with(
        tasks,
        threads,
        SweepOptions { skip_provable_oom: true, ..Default::default() },
    )
    .outcomes;

    let schedule =
        crate::schedule::synthesize(p, m, &vec![cap; p as usize], &CostModel::new(&tight));
    let mut ws = SimWorkspace::new();
    let stats = ws.run(&tight, &schedule, &layout, SimOptions { trace: false, warm: false, recompute: false });
    outcomes.push(SweepOutcome {
        exp_id: tight.id,
        model: tight.model.name.clone(),
        microbatch: tight.parallel.microbatch,
        scenario: "synthesized",
        bound: None,
        stage_bounds: schedule.stage_bounds.clone(),
        layout: layout.name,
        mfu_pct: stats.mfu_pct(),
        makespan: stats.makespan,
        bubble_pct: stats.bubble_fraction * 100.0,
        peak_mem_gib: stats.peak_mem_bytes as f64 / gib,
        per_stage_mem_gib: ws.mem_high_water().iter().map(|&b| b as f64 / gib).collect(),
        oom_stage: stats.oom_stage,
        load_stall_ms: stats.load_stall * 1e3,
        transfer_gib: stats.transfer_bytes as f64 / gib,
    });
    (cap, outcomes)
}

/// Knobs for [`sweep_with`].  The default (all off) makes `sweep_with`
/// behave exactly like [`sweep`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SweepOptions {
    /// Skip cells the static analyzer proves OOM before simulating them
    /// ([`crate::analysis::provably_oom_stage`]: the schedule's own
    /// stash high-water is a lower bound on any execution's peak, so a
    /// static verdict is sound).  Skipped cells still produce a
    /// [`SweepOutcome`] — `oom_stage` set, memory columns from the
    /// static model, timing columns `NaN` (rendered `NaN`, exported as
    /// empty/`null`) — so grids keep their shape.
    pub skip_provable_oom: bool,
    /// Disable the warm-start DES replay and simulate every cell from
    /// scratch ([`SimOptions::warm`] off).  Warm and cold runs are
    /// bit-identical by construction (pinned by the differential test
    /// below); this flag exists for A/B timing (`bpipe sweep
    /// --force-cold`, the bench's warm-vs-cold section) and as the
    /// escape hatch if a future schedule family violates the replay's
    /// assumptions.
    pub force_cold: bool,
    /// Score every cell under the recompute-vs-stash hybrid memory
    /// model ([`SimOptions::recompute`], `bpipe sweep --recompute`):
    /// evictions discard the activation and the matching load pays one
    /// forward recompute at the evicting stage instead of a transfer.
    /// Warm replay composes soundly with this — recompute cells have a
    /// zero-duration Evict, which fails the replay's positive-duration
    /// gate, so they simply run cold.
    pub recompute: bool,
}

/// [`sweep_with`]'s result: the outcomes in task order, plus how many
/// cells the static-analysis gate skipped and the warm-start replay
/// telemetry (events replayed from snapshots vs total events simulated,
/// summed over every worker's [`SimWorkspace`]).
#[derive(Debug, Clone)]
pub struct SweepReport {
    pub outcomes: Vec<SweepOutcome>,
    pub skipped: usize,
    /// total DES events across all simulated cells
    pub events_total: u64,
    /// events satisfied by snapshot replay instead of simulation
    pub events_replayed: u64,
}

/// Simulate every task of the grid across `threads` OS threads (0 =
/// auto).  Each worker owns one [`SimWorkspace`] (reused cell to cell)
/// and writes into its task's indexed slot, so results come back in task
/// order with no post-hoc sort.
pub fn sweep(tasks: Vec<SweepTask>, threads: usize) -> Vec<SweepOutcome> {
    sweep_with(tasks, threads, SweepOptions::default()).outcomes
}

/// [`sweep`] with [`SweepOptions`] — the entry point for the
/// provably-OOM skip gate (`bpipe sweep --skip-oom`).
pub fn sweep_with(tasks: Vec<SweepTask>, threads: usize, opts: SweepOptions) -> SweepReport {
    let threads = if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    } else {
        threads
    };
    let threads = threads.min(tasks.len().max(1));
    let next = AtomicUsize::new(0);
    let skipped = AtomicUsize::new(0);
    let events_total = AtomicU64::new(0);
    let events_replayed = AtomicU64::new(0);
    let slots: Vec<OnceLock<SweepOutcome>> = (0..tasks.len()).map(|_| OnceLock::new()).collect();
    let tasks_ref = &tasks;
    let slots_ref = &slots;
    let skipped_ref = &skipped;
    let totals_ref = (&events_total, &events_replayed);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let mut ws = SimWorkspace::new();
                let mut cache = ScheduleCache::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= tasks_ref.len() {
                        break;
                    }
                    let (out, was_skipped) = run_task_in(&mut ws, &mut cache, &tasks_ref[i], opts);
                    if was_skipped {
                        skipped_ref.fetch_add(1, Ordering::Relaxed);
                    }
                    let _ = slots_ref[i].set(out);
                }
                totals_ref.0.fetch_add(ws.events_total(), Ordering::Relaxed);
                totals_ref.1.fetch_add(ws.events_replayed(), Ordering::Relaxed);
            });
        }
    });
    let outcomes = slots
        .into_iter()
        .map(|s| s.into_inner().expect("every sweep slot is filled exactly once"))
        .collect();
    SweepReport {
        outcomes,
        skipped: skipped.into_inner(),
        events_total: events_total.into_inner(),
        events_replayed: events_replayed.into_inner(),
    }
}

/// Simulate one cell in the given workspace (the worker inner loop), or
/// — with the skip gate on — settle it statically.  The bool is true
/// iff the cell was skipped.
fn run_task_in(
    ws: &mut SimWorkspace,
    cache: &mut ScheduleCache,
    t: &SweepTask,
    opts: SweepOptions,
) -> (SweepOutcome, bool) {
    let gib = (1u64 << 30) as f64;
    let schedule = cache.build_for(&t.spec, &t.experiment);
    // a per-stage-bounds cell reports its bound vector; a uniform
    // rebalance cell its scalar bound; a base cell neither
    let stage_bounds = schedule.stage_bounds.clone();
    let bound = match (schedule.kind, &stage_bounds) {
        (ScheduleKind::BPipe { bound }, None) => Some(bound),
        _ => None,
    };
    if opts.skip_provable_oom {
        if let Some((stage, _)) = crate::analysis::provably_oom_stage(&t.experiment, &schedule) {
            let per_stage = crate::analysis::static_peak_bytes(&t.experiment, &schedule);
            let peak = per_stage.iter().copied().max().unwrap_or(0);
            let out = SweepOutcome {
                exp_id: t.experiment.id,
                model: t.experiment.model.name.clone(),
                microbatch: t.experiment.parallel.microbatch,
                scenario: t.spec.name(),
                bound,
                stage_bounds,
                layout: t.layout.name,
                mfu_pct: f64::NAN,
                makespan: f64::NAN,
                bubble_pct: f64::NAN,
                peak_mem_gib: peak as f64 / gib,
                per_stage_mem_gib: per_stage.iter().map(|&b| b as f64 / gib).collect(),
                oom_stage: Some(stage),
                load_stall_ms: f64::NAN,
                transfer_gib: f64::NAN,
            };
            return (out, true);
        }
    }
    let stats = ws.run(
        &t.experiment,
        &schedule,
        &t.layout,
        SimOptions { trace: false, warm: !opts.force_cold, recompute: opts.recompute },
    );
    let out = SweepOutcome {
        exp_id: t.experiment.id,
        model: t.experiment.model.name.clone(),
        microbatch: t.experiment.parallel.microbatch,
        scenario: t.spec.name(),
        bound,
        stage_bounds,
        layout: t.layout.name,
        mfu_pct: stats.mfu_pct(),
        makespan: stats.makespan,
        bubble_pct: stats.bubble_fraction * 100.0,
        peak_mem_gib: stats.peak_mem_bytes as f64 / gib,
        per_stage_mem_gib: ws.mem_high_water().iter().map(|&b| b as f64 / gib).collect(),
        oom_stage: stats.oom_stage,
        load_stall_ms: stats.load_stall * 1e3,
        transfer_gib: stats.transfer_bytes as f64 / gib,
    };
    (out, false)
}

/// The "k" column of the ranked table: a scalar bound, a per-stage
/// `min..max` range, or `-` for base cells.
fn bound_column(o: &SweepOutcome) -> String {
    match (&o.stage_bounds, o.bound) {
        (Some(bs), _) => {
            let lo = bs.iter().min().copied().unwrap_or(0);
            let hi = bs.iter().max().copied().unwrap_or(0);
            format!("{lo}..{hi}")
        }
        (None, Some(k)) => k.to_string(),
        (None, None) => "-".into(),
    }
}

/// Render the grid as one ranked table: feasible cells by MFU
/// (descending), then OOM cells flagged with the bursting stage.  NaN
/// MFUs (degenerate zero-makespan configs) order last among their
/// feasibility class via `total_cmp`, never panicking the comparator.
pub fn render_sweep(outcomes: &[SweepOutcome]) -> String {
    let mut ranked: Vec<&SweepOutcome> = outcomes.iter().collect();
    ranked.sort_by(|a, b| {
        (a.oom_stage.is_some())
            .cmp(&b.oom_stage.is_some())
            .then(b.mfu_pct.total_cmp(&a.mfu_pct))
    });
    let mut t = Table::new(&[
        "rank", "exp", "model", "b", "scenario", "k", "layout", "MFU %", "iter s", "bubble %",
        "peak GiB", "stall ms", "xfer GiB", "verdict",
    ]);
    for (rank, o) in ranked.iter().enumerate() {
        let verdict = match o.oom_stage {
            Some(s) => format!("OOM @ stage {s}"),
            None => "fits".to_string(),
        };
        t.push(vec![
            (rank + 1).to_string(),
            o.exp_id.map(|i| format!("({i})")).unwrap_or_default(),
            o.model.clone(),
            o.microbatch.to_string(),
            o.scenario.to_string(),
            bound_column(o),
            o.layout.to_string(),
            format!("{:.1}", o.mfu_pct),
            format!("{:.2}", o.makespan),
            format!("{:.1}", o.bubble_pct),
            format!("{:.1}", o.peak_mem_gib),
            format!("{:.1}", o.load_stall_ms),
            format!("{:.2}", o.transfer_gib),
            verdict,
        ]);
    }
    t.render()
}

/// Condense a bounds grid into one frontier row per
/// (experiment, scenario, layout): the swept bound range, the tightest
/// bound that still fits, the knee (tightest bound within 0.5% of the
/// group's best MFU), and the stall/memory cost at the extremes.
pub fn render_bound_frontier(outcomes: &[SweepOutcome]) -> String {
    // group by (experiment identity, scenario, layout), keeping cells
    // sorted by bound desc; model + microbatch keep custom (id-less)
    // experiment configs from collapsing into one group
    type GroupKey<'a> = (Option<u32>, &'a str, u64, &'static str, &'static str);
    let mut groups: BTreeMap<GroupKey<'_>, Vec<&SweepOutcome>> = BTreeMap::new();
    for o in outcomes {
        if o.bound.is_none() {
            continue; // not a bound-sweep cell
        }
        groups
            .entry((o.exp_id, o.model.as_str(), o.microbatch, o.scenario, o.layout))
            .or_default()
            .push(o);
    }
    let mut t = Table::new(&[
        "exp", "model", "b", "scenario", "layout", "bounds", "fit ≥k", "knee k", "best k",
        "best MFU %", "stall@knee ms", "peak@knee GiB",
    ]);
    for ((_, _, _, scenario, layout), mut cells) in groups {
        cells.sort_by(|a, b| b.bound.cmp(&a.bound));
        let hi = cells.first().and_then(|o| o.bound).unwrap_or(2);
        let lo = cells.last().and_then(|o| o.bound).unwrap_or(2);
        let fits: Vec<&&SweepOutcome> = cells.iter().filter(|o| o.oom_stage.is_none()).collect();
        let min_fit = fits.iter().filter_map(|o| o.bound).min();
        let best = fits
            .iter()
            .max_by(|a, b| a.mfu_pct.total_cmp(&b.mfu_pct).then(b.bound.cmp(&a.bound)));
        let best_mfu = best.map(|o| o.mfu_pct).unwrap_or(f64::NAN);
        let knee = fits
            .iter()
            .filter(|o| o.mfu_pct >= best_mfu * 0.995)
            .filter_map(|o| o.bound)
            .min();
        let knee_cell = knee.and_then(|k| cells.iter().find(|o| o.bound == Some(k)));
        let o0 = cells[0];
        t.push(vec![
            o0.exp_id.map(|i| format!("({i})")).unwrap_or_default(),
            o0.model.clone(),
            o0.microbatch.to_string(),
            scenario.to_string(),
            layout.to_string(),
            format!("{hi}..{lo}"),
            min_fit.map(|k| k.to_string()).unwrap_or_else(|| "never".into()),
            knee.map(|k| k.to_string()).unwrap_or_else(|| "-".into()),
            best.and_then(|o| o.bound).map(|k| k.to_string()).unwrap_or_else(|| "-".into()),
            if best_mfu.is_finite() { format!("{best_mfu:.1}") } else { "-".into() },
            knee_cell.map(|o| format!("{:.1}", o.load_stall_ms)).unwrap_or_else(|| "-".into()),
            knee_cell.map(|o| format!("{:.1}", o.peak_mem_gib)).unwrap_or_else(|| "-".into()),
        ]);
    }
    t.render()
}

/// Export every cell as CSV (full precision, one row per outcome).
/// Non-finite values become empty fields — the CSV cousin of the JSON
/// writer's `null` (strict numeric consumers reject a literal "NaN").
/// The two trailing vector columns (`stage_bounds`,
/// `per_stage_mem_gib`) are comma-joined inside one field, so
/// [`Table::render_csv`] quotes them per RFC 4180.
pub fn sweep_to_csv(outcomes: &[SweepOutcome]) -> String {
    let num = |v: f64| if v.is_finite() { format!("{v}") } else { String::new() };
    let mut t = Table::new(&[
        "exp", "model", "microbatch", "scenario", "bound", "layout", "mfu_pct", "makespan_s",
        "bubble_pct", "peak_mem_gib", "oom_stage", "load_stall_ms", "transfer_gib",
        "stage_bounds", "per_stage_mem_gib",
    ]);
    for o in outcomes {
        t.push(vec![
            o.exp_id.map(|i| i.to_string()).unwrap_or_default(),
            o.model.clone(),
            o.microbatch.to_string(),
            o.scenario.to_string(),
            o.bound.map(|k| k.to_string()).unwrap_or_default(),
            o.layout.to_string(),
            num(o.mfu_pct),
            num(o.makespan),
            num(o.bubble_pct),
            num(o.peak_mem_gib),
            o.oom_stage.map(|s| s.to_string()).unwrap_or_default(),
            num(o.load_stall_ms),
            num(o.transfer_gib),
            o.stage_bounds
                .as_ref()
                .map(|bs| {
                    bs.iter().map(|k| k.to_string()).collect::<Vec<_>>().join(",")
                })
                .unwrap_or_default(),
            o.per_stage_mem_gib
                .iter()
                .map(|g| num(*g))
                .collect::<Vec<_>>()
                .join(","),
        ]);
    }
    t.render_csv()
}

/// Export every cell as a JSON array of objects (via [`crate::util::Json`]).
pub fn sweep_to_json(outcomes: &[SweepOutcome]) -> Json {
    Json::Arr(
        outcomes
            .iter()
            .map(|o| {
                Json::obj(vec![
                    (
                        "exp",
                        o.exp_id.map(|i| Json::Num(i as f64)).unwrap_or(Json::Null),
                    ),
                    ("model", Json::str(&o.model)),
                    ("microbatch", Json::Num(o.microbatch as f64)),
                    ("scenario", Json::str(o.scenario)),
                    (
                        "bound",
                        o.bound.map(|k| Json::Num(k as f64)).unwrap_or(Json::Null),
                    ),
                    (
                        "stage_bounds",
                        o.stage_bounds
                            .as_ref()
                            .map(|bs| {
                                Json::Arr(bs.iter().map(|&k| Json::Num(k as f64)).collect())
                            })
                            .unwrap_or(Json::Null),
                    ),
                    ("layout", Json::str(o.layout)),
                    ("mfu_pct", Json::Num(o.mfu_pct)),
                    ("makespan_s", Json::Num(o.makespan)),
                    ("bubble_pct", Json::Num(o.bubble_pct)),
                    ("peak_mem_gib", Json::Num(o.peak_mem_gib)),
                    (
                        "oom_stage",
                        o.oom_stage.map(|s| Json::Num(s as f64)).unwrap_or(Json::Null),
                    ),
                    ("load_stall_ms", Json::Num(o.load_stall_ms)),
                    ("transfer_gib", Json::Num(o.transfer_gib)),
                    (
                        "per_stage_mem_gib",
                        Json::Arr(o.per_stage_mem_gib.iter().map(|&g| Json::Num(g)).collect()),
                    ),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_experiment;

    fn small_grid() -> Vec<SweepTask> {
        // one experiment, all scenarios, both layouts — cheap enough for CI
        experiment_tasks(&paper_experiment(8).unwrap(), 2)
    }

    /// Simulate one cell with a throwaway workspace (serial reference).
    fn run_task(t: &SweepTask) -> SweepOutcome {
        run_task_in(&mut SimWorkspace::new(), &mut ScheduleCache::new(), t, SweepOptions::default())
            .0
    }

    #[test]
    fn skip_gate_settles_provable_ooms_statically_and_soundly() {
        let report = sweep_with(
            small_grid(),
            0,
            SweepOptions { skip_provable_oom: true, ..Default::default() },
        );
        let full = sweep(small_grid(), 0);
        assert_eq!(report.outcomes.len(), full.len());
        assert!(report.skipped > 0, "exp 8 has provably-OOM cells (GPipe base, 1F1B base)");
        let mut seen_skipped = 0;
        for (gated, des) in report.outcomes.iter().zip(full.iter()) {
            assert_eq!(gated.scenario, des.scenario);
            assert_eq!(gated.layout, des.layout);
            if gated.mfu_pct.is_nan() {
                // statically settled: the DES must agree the cell OOMs
                // (soundness of the lower-bound gate); memory columns
                // come from the static model and stay finite
                seen_skipped += 1;
                assert!(
                    des.oom_stage.is_some(),
                    "{} / {}: skipped statically but the DES fits",
                    gated.scenario,
                    gated.layout
                );
                assert!(gated.oom_stage.is_some() && gated.peak_mem_gib.is_finite());
            } else {
                // un-skipped cells are simulated exactly as before
                assert_eq!(gated.mfu_pct, des.mfu_pct, "{} / {}", gated.scenario, gated.layout);
                assert_eq!(gated.oom_stage, des.oom_stage);
            }
        }
        assert_eq!(seen_skipped, report.skipped);
        // default options leave the driver untouched
        let plain = sweep_with(small_grid(), 0, SweepOptions::default());
        assert_eq!(plain.skipped, 0);
        assert_eq!(plain.outcomes.len(), full.len());
    }

    #[test]
    fn schedule_cache_matches_uncached_builds() {
        // the cache is a pure memoization: across a realistic worker
        // stream (bound cells family-by-family, then ranking cells with
        // base/rebalance/per-stage interleaved) every schedule must be
        // op-identical to the uncached ScenarioSpec build
        let e = paper_experiment(8).unwrap();
        let mut cache = ScheduleCache::new();
        let mut stream: Vec<ScenarioSpec> = Vec::new();
        stream.extend(bound_sensitivity_tasks(&e, 2).into_iter().map(|t| t.spec));
        stream.extend(experiment_tasks(&e, 2).into_iter().map(|t| t.spec));
        assert!(!stream.is_empty());
        for spec in stream {
            assert_eq!(cache.build_for(&spec, &e), spec.build_for(&e), "{}", spec.name());
        }
    }

    #[test]
    fn parallel_sweep_matches_serial() {
        let serial: Vec<f64> = small_grid().iter().map(|t| run_task(t).mfu_pct).collect();
        let parallel: Vec<f64> = sweep(small_grid(), 4).into_iter().map(|o| o.mfu_pct).collect();
        assert_eq!(serial, parallel, "sweep must be deterministic and order-stable");
    }

    #[test]
    fn grid_covers_all_scenarios_and_layouts() {
        let outs = sweep(small_grid(), 0);
        assert_eq!(outs.len(), 15 * 2);
        for scenario in [
            "1F1B", "1F1B+rebalance", "1F1B+stage-bounds", "GPipe", "GPipe+rebalance",
            "GPipe+stage-bounds", "interleaved", "interleaved+rebalance",
            "interleaved+stage-bounds", "V-shaped", "V-shaped+rebalance",
            "V-shaped+stage-bounds", "W-shaped", "W-shaped+rebalance", "W-shaped+stage-bounds",
        ] {
            assert_eq!(outs.iter().filter(|o| o.scenario == scenario).count(), 2, "{scenario}");
        }
        for o in &outs {
            // uniformly rebalanced cells report the scalar bound applied;
            // per-stage cells report the full bound vector instead
            assert_eq!(o.bound.is_some(), o.scenario.ends_with("+rebalance"), "{}", o.scenario);
            assert_eq!(
                o.stage_bounds.is_some(),
                o.scenario.ends_with("+stage-bounds"),
                "{}",
                o.scenario
            );
            assert_eq!(
                o.per_stage_mem_gib.len() as u64,
                paper_experiment(8).unwrap().parallel.p,
                "{}",
                o.scenario
            );
        }
    }

    #[test]
    fn rebalance_rescues_exp8_1f1b() {
        // the sweep must show the paper's core claim as a ranking fact:
        // plain 1F1B OOMs on exp (8), 1F1B+rebalance fits
        let outs = sweep(small_grid(), 0);
        let find = |scenario: &str, layout: &str| {
            outs.iter()
                .find(|o| o.scenario == scenario && o.layout == layout)
                .unwrap()
        };
        assert_eq!(find("1F1B", "pair-adjacent").oom_stage, Some(0));
        assert!(find("1F1B+rebalance", "pair-adjacent").oom_stage.is_none());
    }

    #[test]
    fn render_ranks_fits_above_oom() {
        let outs = sweep(small_grid(), 0);
        let txt = render_sweep(&outs);
        assert!(txt.contains("OOM @ stage"));
        assert!(txt.contains("fits"));
        // every OOM row ranks below every fitting row
        let lines: Vec<&str> = txt.lines().collect();
        let first_oom = lines.iter().position(|l| l.contains("OOM @")).unwrap();
        assert!(lines[first_oom..].iter().all(|l| !l.contains("| fits")));
    }

    #[test]
    fn paper_grid_is_full_size() {
        let tasks = paper_grid(2);
        assert_eq!(tasks.len(), 10 * 15 * 2);
    }

    #[test]
    fn per_stage_cells_fit_where_uniform_base_ooms() {
        // the stage-bounds scenario earns its grid slot: on exp (8) it
        // rescues 1F1B (like the uniform rebalance) but moves less data
        let outs = sweep(small_grid(), 0);
        let find = |scenario: &str| {
            outs.iter()
                .find(|o| o.scenario == scenario && o.layout == "pair-adjacent")
                .unwrap()
        };
        let per = find("1F1B+stage-bounds");
        let uni = find("1F1B+rebalance");
        assert_eq!(per.oom_stage, None);
        assert!(per.transfer_gib < uni.transfer_gib);
        assert_eq!(per.stage_bounds, Some(vec![5, 6, 6, 5, 4, 3, 2, 2]));
    }

    #[test]
    fn bounds_grid_is_ten_times_bigger() {
        // the acceptance bar: ≥1000 bound-sensitivity cells, covering
        // every bound from derived down to 2 for every family
        let tasks = bounds_grid(2);
        assert!(tasks.len() >= 1000, "only {} cells", tasks.len());
        assert!(
            tasks.len() >= 10 * paper_grid(2).len(),
            "{} cells is not >=10x the {}-cell ranking grid",
            tasks.len(),
            paper_grid(2).len()
        );
        for t in &tasks {
            assert!(t.spec.rebalance && t.spec.bound.unwrap() >= 2);
        }
        // every rebalanceable family contributes cells (dropping one —
        // e.g. GPipe, the largest — would silently shrink the grid)
        for family in [
            Family::OneFOneB,
            Family::GPipe,
            Family::Interleaved { v: 2 },
            Family::VShaped,
            Family::ZigZag { v: 4 },
        ] {
            assert!(
                tasks.iter().any(|t| t.spec.family == family),
                "{family:?} missing from the bounds grid"
            );
        }
        // exp 8 interleaved v=2 derives bound 16 → bounds 16..2 × 4 layouts
        let il2 = Family::Interleaved { v: 2 };
        let e8_il: Vec<_> = tasks
            .iter()
            .filter(|t| t.experiment.id == Some(8) && t.spec.family == il2)
            .collect();
        assert_eq!(e8_il.len(), 15 * 4);
        // all four layouts present, each with the full descending range
        for name in ["pair-adjacent", "sequential", "scatter", "ring"] {
            let bounds: Vec<u64> = e8_il
                .iter()
                .filter(|t| t.layout.name == name)
                .map(|t| t.spec.bound.unwrap())
                .collect();
            assert_eq!(bounds.len(), 15, "{name}");
            assert!(bounds.windows(2).all(|w| w[0] == w[1] + 1), "{name} not descending");
        }
    }

    #[test]
    fn bound_sensitivity_traces_the_stall_frontier() {
        // one experiment end to end through the driver: tighter bounds on
        // the sequential layout must (weakly) increase load stall, and
        // the report + exports must carry the bound column
        let e = paper_experiment(8).unwrap();
        let tasks: Vec<SweepTask> = bound_sensitivity_tasks(&e, 2)
            .into_iter()
            .filter(|t| t.spec.family == Family::OneFOneB && t.layout.name == "sequential")
            .collect();
        let bounds: Vec<u64> = tasks.iter().map(|t| t.spec.bound.unwrap()).collect();
        assert_eq!(bounds, vec![5, 4, 3, 2], "1F1B derives ⌈(p+2)/2⌉ = 5 at p=8");
        let outs = sweep(tasks, 2);
        let stall_hi = outs.first().unwrap().load_stall_ms; // bound 5
        let stall_lo = outs.last().unwrap().load_stall_ms; // bound 2
        assert!(
            stall_lo > stall_hi,
            "tightening 5→2 must add stall: {stall_hi:.1} → {stall_lo:.1} ms"
        );
        let frontier = render_bound_frontier(&outs);
        assert!(frontier.contains("5..2"), "{frontier}");
        let csv = sweep_to_csv(&outs);
        assert!(csv.lines().count() == outs.len() + 1 && csv.contains("bound"));
    }

    /// Deep-clone a task list (tasks share experiments via `Arc`).
    fn clone_tasks(ts: &[SweepTask]) -> Vec<SweepTask> {
        ts.iter()
            .map(|t| SweepTask {
                experiment: Arc::clone(&t.experiment),
                spec: t.spec,
                layout: t.layout.clone(),
            })
            .collect()
    }

    #[test]
    fn warm_sweep_is_bit_identical_to_cold() {
        // the tentpole invariant: warm-start replay is a pure
        // optimization — every SweepOutcome bit-identical to a cold
        // run, on the descending-bound grid AND the mixed ranking grid
        // (which exercises the incompatible-snapshot fallback between
        // families/layouts)
        let e = paper_experiment(8).unwrap();
        let mut tasks = bound_sensitivity_tasks(&e, 2);
        tasks.extend(experiment_tasks(&e, 2));
        let warm = sweep_with(clone_tasks(&tasks), 1, SweepOptions::default());
        let cold = sweep_with(tasks, 1, SweepOptions { force_cold: true, ..Default::default() });
        assert_eq!(cold.events_replayed, 0, "force_cold must disable replay");
        assert!(warm.events_replayed > 0, "descending bounds must replay a prefix");
        assert!(warm.events_replayed < warm.events_total);
        assert_eq!(warm.events_total, cold.events_total);
        assert_eq!(warm.outcomes.len(), cold.outcomes.len());
        for (w, c) in warm.outcomes.iter().zip(cold.outcomes.iter()) {
            // SweepOutcome carries floats; the Debug rendering
            // round-trips every finite f64, so string equality pins
            // bit-identity across all fields at once
            assert_eq!(
                format!("{w:?}"),
                format!("{c:?}"),
                "warm != cold at {} k={:?} {}",
                w.scenario,
                w.bound,
                w.layout
            );
        }
    }

    #[test]
    fn warm_replay_telemetry_hits_the_event_floor() {
        // ≥50% replayed, provable by construction: run every
        // descending-bound cell back-to-back twice (the shape of
        // synthesize-style repeated candidate evaluation).  The second
        // run of each pair presents an identical op/duration stream, so
        // the divergence horizon never fires and its entire event
        // stream replays from the snapshot; the honest prefix reuse
        // between adjacent bounds (asserted > 0 above) rides on top.
        let e = paper_experiment(8).unwrap();
        let tasks: Vec<SweepTask> = bound_sensitivity_tasks(&e, 2)
            .into_iter()
            .flat_map(|t| {
                let twin = SweepTask {
                    experiment: Arc::clone(&t.experiment),
                    spec: t.spec,
                    layout: t.layout.clone(),
                };
                [t, twin]
            })
            .collect();
        let report = sweep_with(tasks, 1, SweepOptions::default());
        assert!(report.events_total > 0);
        assert!(
            report.events_replayed * 2 >= report.events_total,
            "replayed {} of {} events (< 50%)",
            report.events_replayed,
            report.events_total
        );
    }

    #[test]
    fn csv_and_json_exports_are_valid_and_complete() {
        let outs = sweep(small_grid(), 0);
        let csv = sweep_to_csv(&outs);
        assert_eq!(csv.lines().count(), outs.len() + 1);
        assert!(csv.starts_with("exp,model,microbatch,scenario,bound,layout,mfu_pct"));
        let json = sweep_to_json(&outs);
        let parsed = Json::parse(&json.to_string()).expect("export must be valid JSON");
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr.len(), outs.len());
        let first = &arr[0];
        assert_eq!(first.get("scenario").unwrap().as_str(), Some("1F1B"));
        assert_eq!(first.get("exp").unwrap().as_u64(), Some(8));
        assert!(first.get("mfu_pct").unwrap().as_f64().unwrap() > 0.0);
    }
}
