//! `sim::sweep` — the parallel design-space sweep driver.
//!
//! The paper answers "does memory rebalancing pay off?" for exactly one
//! schedule (1F1B).  With [`crate::bpipe::rebalance`] schedule-agnostic,
//! the interesting space is the grid
//!
//! ```text
//! experiment (Table 3 rows) × schedule scenario × device layout
//! ```
//!
//! where the scenarios cover the three memory-management families:
//! imbalanced (1F1B, GPipe), anti-balanced virtual pipelines
//! (interleaved), balanced-by-placement (V-shaped), each ± the
//! rebalancing transform at its derived bound.
//!
//! [`sweep`] fans the grid out over a pool of OS threads (scoped; the
//! build is offline, so no rayon — a work-stealing index over a shared
//! task list gives the same shape), simulates every cell through the
//! dense-index DES engine, and [`render_sweep`] emits one ranked report
//! table: feasible cells sorted by MFU, infeasible (OOM) cells flagged
//! at the bottom with the stage that burst.
//!
//! `bpipe sweep` on the CLI runs the whole grid in one command.

use super::engine::simulate;
use crate::bpipe::{pair_adjacent_layout, rebalance, sequential_layout, Layout};
use crate::config::{paper_experiments, ExperimentConfig};
use crate::report::Table;
use crate::schedule::{gpipe, interleaved, one_f_one_b, v_shaped, Schedule};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// One cell of the sweep grid, before simulation.
pub struct SweepTask {
    pub experiment: ExperimentConfig,
    pub scenario: &'static str,
    pub layout: Layout,
    pub schedule: Schedule,
}

/// One simulated cell of the grid.
#[derive(Debug, Clone)]
pub struct SweepOutcome {
    pub exp_id: Option<u32>,
    pub model: String,
    pub microbatch: u64,
    pub scenario: &'static str,
    pub layout: &'static str,
    pub mfu_pct: f64,
    pub makespan: f64,
    pub bubble_pct: f64,
    pub peak_mem_gib: f64,
    pub oom_stage: Option<u64>,
    pub load_stall_ms: f64,
    pub transfer_gib: f64,
}

/// The schedule scenarios swept for one experiment: the three scheduling
/// families ± rebalancing (GPipe as the memory-worst-case baseline).
pub fn scenarios(p: u64, m: u64, v: u64) -> Vec<(&'static str, Schedule)> {
    let base_1f1b = one_f_one_b(p, m);
    let base_il = interleaved(p, m, v);
    let base_v = v_shaped(p, m);
    vec![
        ("1F1B", base_1f1b.clone()),
        ("1F1B+rebalance", rebalance(&base_1f1b, None)),
        ("GPipe", gpipe(p, m)),
        ("interleaved", base_il.clone()),
        ("interleaved+rebalance", rebalance(&base_il, None)),
        ("V-shaped", base_v.clone()),
        ("V-shaped+rebalance", rebalance(&base_v, None)),
    ]
}

/// All sweep tasks for one experiment: every scenario × the
/// {pair-adjacent, sequential} layouts — the one place the grid's inner
/// dimensions are defined (paper_grid, the CLI and the tests all build
/// on it).
pub fn experiment_tasks(e: &ExperimentConfig, v: u64) -> Vec<SweepTask> {
    let p = e.parallel.p;
    let m = e.parallel.num_microbatches();
    let mut tasks = Vec::new();
    for (scenario, schedule) in scenarios(p, m, v) {
        for layout in [
            pair_adjacent_layout(p, e.cluster.n_nodes),
            sequential_layout(p, e.cluster.n_nodes),
        ] {
            tasks.push(SweepTask {
                experiment: e.clone(),
                scenario,
                layout,
                schedule: schedule.clone(),
            });
        }
    }
    tasks
}

/// Build the full paper grid: every Table-3 experiment × every scenario ×
/// {pair-adjacent, sequential} layout.
pub fn paper_grid(v: u64) -> Vec<SweepTask> {
    paper_experiments().iter().flat_map(|e| experiment_tasks(e, v)).collect()
}

/// Simulate every task of the grid across `threads` OS threads (0 =
/// auto).  Results come back in task order regardless of which worker
/// ran them.
pub fn sweep(tasks: Vec<SweepTask>, threads: usize) -> Vec<SweepOutcome> {
    let threads = if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    } else {
        threads
    };
    let threads = threads.min(tasks.len().max(1));
    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<(usize, SweepOutcome)>> = Mutex::new(Vec::with_capacity(tasks.len()));
    let tasks_ref = &tasks;
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= tasks_ref.len() {
                    break;
                }
                let t = &tasks_ref[i];
                let out = run_task(t);
                results.lock().unwrap().push((i, out));
            });
        }
    });
    let mut results = results.into_inner().unwrap();
    results.sort_by_key(|(i, _)| *i);
    results.into_iter().map(|(_, o)| o).collect()
}

fn run_task(t: &SweepTask) -> SweepOutcome {
    let gib = (1u64 << 30) as f64;
    let r = simulate(&t.experiment, &t.schedule, &t.layout);
    SweepOutcome {
        exp_id: t.experiment.id,
        model: t.experiment.model.name.clone(),
        microbatch: t.experiment.parallel.microbatch,
        scenario: t.scenario,
        layout: t.layout.name,
        mfu_pct: r.mfu_pct(),
        makespan: r.makespan,
        bubble_pct: r.bubble_fraction * 100.0,
        peak_mem_gib: *r.mem_high_water.iter().max().unwrap() as f64 / gib,
        oom_stage: r.oom_stage,
        load_stall_ms: r.load_stall * 1e3,
        transfer_gib: r.transfer_bytes as f64 / gib,
    }
}

/// Render the grid as one ranked table: feasible cells by MFU
/// (descending), then OOM cells flagged with the bursting stage.
pub fn render_sweep(outcomes: &[SweepOutcome]) -> String {
    let mut ranked: Vec<&SweepOutcome> = outcomes.iter().collect();
    ranked.sort_by(|a, b| {
        (a.oom_stage.is_some())
            .cmp(&b.oom_stage.is_some())
            .then(b.mfu_pct.partial_cmp(&a.mfu_pct).unwrap())
    });
    let mut t = Table::new(&[
        "rank", "exp", "model", "b", "scenario", "layout", "MFU %", "iter s", "bubble %",
        "peak GiB", "stall ms", "xfer GiB", "verdict",
    ]);
    for (rank, o) in ranked.iter().enumerate() {
        let verdict = match o.oom_stage {
            Some(s) => format!("OOM @ stage {s}"),
            None => "fits".to_string(),
        };
        t.push(vec![
            (rank + 1).to_string(),
            o.exp_id.map(|i| format!("({i})")).unwrap_or_default(),
            o.model.clone(),
            o.microbatch.to_string(),
            o.scenario.to_string(),
            o.layout.to_string(),
            format!("{:.1}", o.mfu_pct),
            format!("{:.2}", o.makespan),
            format!("{:.1}", o.bubble_pct),
            format!("{:.1}", o.peak_mem_gib),
            format!("{:.1}", o.load_stall_ms),
            format!("{:.2}", o.transfer_gib),
            verdict,
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_experiment;

    fn small_grid() -> Vec<SweepTask> {
        // one experiment, all scenarios, both layouts — cheap enough for CI
        experiment_tasks(&paper_experiment(8).unwrap(), 2)
    }

    #[test]
    fn parallel_sweep_matches_serial() {
        let serial: Vec<f64> = small_grid().into_iter().map(|t| run_task(&t).mfu_pct).collect();
        let parallel: Vec<f64> = sweep(small_grid(), 4).into_iter().map(|o| o.mfu_pct).collect();
        assert_eq!(serial, parallel, "sweep must be deterministic and order-stable");
    }

    #[test]
    fn grid_covers_all_scenarios_and_layouts() {
        let outs = sweep(small_grid(), 0);
        assert_eq!(outs.len(), 7 * 2);
        for scenario in [
            "1F1B", "1F1B+rebalance", "GPipe", "interleaved", "interleaved+rebalance",
            "V-shaped", "V-shaped+rebalance",
        ] {
            assert_eq!(outs.iter().filter(|o| o.scenario == scenario).count(), 2, "{scenario}");
        }
    }

    #[test]
    fn rebalance_rescues_exp8_1f1b() {
        // the sweep must show the paper's core claim as a ranking fact:
        // plain 1F1B OOMs on exp (8), 1F1B+rebalance fits
        let outs = sweep(small_grid(), 0);
        let find = |scenario: &str, layout: &str| {
            outs.iter()
                .find(|o| o.scenario == scenario && o.layout == layout)
                .unwrap()
        };
        assert_eq!(find("1F1B", "pair-adjacent").oom_stage, Some(0));
        assert!(find("1F1B+rebalance", "pair-adjacent").oom_stage.is_none());
    }

    #[test]
    fn render_ranks_fits_above_oom() {
        let outs = sweep(small_grid(), 0);
        let txt = render_sweep(&outs);
        assert!(txt.contains("OOM @ stage"));
        assert!(txt.contains("fits"));
        // every OOM row ranks below every fitting row
        let lines: Vec<&str> = txt.lines().collect();
        let first_oom = lines.iter().position(|l| l.contains("OOM @")).unwrap();
        assert!(lines[first_oom..].iter().all(|l| !l.contains("| fits")));
    }

    #[test]
    fn paper_grid_is_full_size() {
        let tasks = paper_grid(2);
        assert_eq!(tasks.len(), 10 * 7 * 2);
    }
}
