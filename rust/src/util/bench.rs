//! Tiny micro-benchmark harness (offline stand-in for criterion).
//!
//! Warms up, runs timed iterations until a wall-clock budget or max
//! iteration count is hit, and reports mean/median/min/stddev.  Used by
//! every file under `benches/` (all `harness = false`).

use std::time::{Duration, Instant};

/// Summary statistics of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub stddev: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} median {:>12} mean {:>12} min  ±{:>10}  ({} iters)",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.mean),
            fmt_dur(self.min),
            fmt_dur(self.stddev),
            self.iters
        )
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Benchmark `f`, printing and returning the stats.
///
/// Runs 1 warmup call, then up to `max_iters` timed calls or ~2 s of
/// wall clock, whichever comes first (min 3 timed calls).
pub fn bench<T>(name: &str, max_iters: u32, mut f: impl FnMut() -> T) -> BenchResult {
    let _warm = f();
    let budget = Duration::from_secs(2);
    let mut samples = Vec::new();
    let t_total = Instant::now();
    while (samples.len() < 3 || t_total.elapsed() < budget) && (samples.len() as u32) < max_iters {
        let t = Instant::now();
        let out = f();
        samples.push(t.elapsed());
        std::hint::black_box(&out);
    }
    samples.sort();
    let n = samples.len() as u32;
    let sum: Duration = samples.iter().sum();
    let mean = sum / n;
    let median = samples[samples.len() / 2];
    let min = samples[0];
    let mean_ns = mean.as_secs_f64();
    let var = samples
        .iter()
        .map(|s| (s.as_secs_f64() - mean_ns).powi(2))
        .sum::<f64>()
        / n.max(2).saturating_sub(1) as f64;
    let result = BenchResult {
        name: name.to_string(),
        iters: n,
        mean,
        median,
        min,
        stddev: Duration::from_secs_f64(var.sqrt()),
    };
    println!("{}", result.report());
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let r = bench("noop", 50, || 1 + 1);
        assert!(r.iters >= 3);
        assert!(r.min <= r.median && r.median <= r.mean * 10);
        assert!(r.report().contains("noop"));
    }

    #[test]
    fn respects_max_iters() {
        let r = bench("capped", 5, || std::thread::sleep(Duration::from_millis(1)));
        assert!(r.iters <= 5);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500 ns");
        assert!(fmt_dur(Duration::from_micros(1500)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).contains(" s"));
    }
}
