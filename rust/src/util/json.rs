//! Minimal JSON parser **and writer** — enough for
//! `artifacts/manifest.json` and the sweep's `--json` export.
//!
//! Supports objects, arrays, strings (with \\-escapes), numbers, bools
//! and null.  Strict enough to reject malformed documents; small enough
//! to audit.  This is the rust half of the python→rust interchange
//! contract (python/compile/aot.py writes the manifest with the standard
//! library's `json.dumps`).
//!
//! Writing goes through [`Json`]'s `Display` impl: object keys are
//! emitted in sorted order (deterministic output despite the `HashMap`
//! storage), strings are escaped, and non-finite numbers serialize as
//! `null` (JSON has no NaN/inf).  Every document the writer emits
//! round-trips through [`Json::parse`].

use std::collections::HashMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(HashMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub at: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // -- typed accessors (None on type mismatch) ---------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&HashMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= u64::MAX as f64 {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    /// Convenience constructor: an object from `(key, value)` pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Convenience constructor: a string value.
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

/// Escape a string into a JSON string literal (quotes included).
fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\t' => f.write_str("\\t")?,
            '\r' => f.write_str("\\r")?,
            '\u{8}' => f.write_str("\\b")?,
            '\u{c}' => f.write_str("\\f")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

impl fmt::Display for Json {
    /// Compact JSON serialization; parseable by [`Json::parse`] (and any
    /// other JSON parser).  Object keys are sorted for determinism.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) if !n.is_finite() => f.write_str("null"),
            Json::Num(n) => write!(f, "{n}"),
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                f.write_str("[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{v}")?;
                }
                f.write_str("]")
            }
            Json::Obj(m) => {
                let mut keys: Vec<&String> = m.keys().collect();
                keys.sort();
                f.write_str("{")?;
                for (i, k) in keys.into_iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(f, k)?;
                    f.write_str(":")?;
                    write!(f, "{}", m[k])?;
                }
                f.write_str("}")
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { at: self.i, msg: msg.into() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = HashMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // advance over one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
            "spec": {"family": "llama", "h": 256, "b": 2},
            "params": {"first": 1181184, "mid": 1115136},
            "bs_sweep": [1, 2, 4],
            "artifacts": {
                "mid_fwd": {"file": "mid_fwd.hlo.txt",
                            "inputs": [{"shape": [2, 128, 256], "dtype": "f32"}],
                            "outputs": []}
            }
        }"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("spec").unwrap().get("h").unwrap().as_u64(), Some(256));
        assert_eq!(v.get("bs_sweep").unwrap().as_arr().unwrap().len(), 3);
        let shape = v
            .get("artifacts").unwrap()
            .get("mid_fwd").unwrap()
            .get("inputs").unwrap()
            .as_arr().unwrap()[0]
            .get("shape").unwrap();
        let dims: Vec<u64> = shape.as_arr().unwrap().iter().map(|d| d.as_u64().unwrap()).collect();
        assert_eq!(dims, vec![2, 128, 256]);
    }

    #[test]
    fn scalars_and_escapes() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(
            Json::parse(r#""a\nbA ü""#).unwrap().as_str(),
            Some("a\nbA ü")
        );
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::parse(" [ 1 , [ ] ] ").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["", "{", "[1,", "{\"a\" 1}", "tru", "1 2", "\"unterminated", "{,}"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn writer_round_trips_through_parser() {
        let doc = Json::obj(vec![
            ("name", Json::str("sweep \"cell\"\n")),
            ("mfu", Json::Num(48.67321)),
            ("oom", Json::Null),
            ("fits", Json::Bool(true)),
            ("hw", Json::Arr(vec![Json::Num(1.0), Json::Num(-2.5), Json::Num(0.0)])),
        ]);
        let text = doc.to_string();
        assert_eq!(Json::parse(&text).unwrap(), doc);
    }

    #[test]
    fn writer_sorts_keys_deterministically() {
        let doc = Json::obj(vec![("b", Json::Num(2.0)), ("a", Json::Num(1.0))]);
        assert_eq!(doc.to_string(), r#"{"a":1,"b":2}"#);
    }

    #[test]
    fn writer_maps_non_finite_to_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Json::Arr(vec![Json::Num(1.5)]).to_string(), "[1.5]");
    }

    #[test]
    fn writer_escapes_control_characters() {
        let s = Json::Str("a\u{1}b".into()).to_string();
        assert_eq!(s, "\"a\\u0001b\"");
        assert_eq!(Json::parse(&s).unwrap().as_str(), Some("a\u{1}b"));
    }

    #[test]
    fn u64_accessor_rejects_fractions_and_negatives() {
        assert_eq!(Json::parse("3.5").unwrap().as_u64(), None);
        assert_eq!(Json::parse("-2").unwrap().as_u64(), None);
        assert_eq!(Json::parse("42").unwrap().as_u64(), Some(42));
    }
}
