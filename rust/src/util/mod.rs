//! In-tree utility substrates (the build is fully offline: no serde, no
//! rand, no criterion — these small, tested replacements cover what the
//! stack needs).

pub mod bench;
pub mod json;
pub mod rng;

pub use bench::{bench, BenchResult};
pub use json::Json;
pub use rng::SplitMix64;
