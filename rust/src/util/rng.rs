//! SplitMix64 PRNG — deterministic, seedable, dependency-free.
//!
//! Used by the synthetic-corpus generator, the property-test drivers and
//! the stage-bench input filler.  SplitMix64 (Steele et al. 2014) passes
//! BigCrush and is the standard seeder for xoshiro; more than enough for
//! workload generation.

/// SplitMix64 state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // rejection sampling to kill modulo bias
        let zone = u64::MAX - u64::MAX % n;
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f32 in [-scale, scale) — bench/test tensor filler.
    pub fn f32_sym(&mut self, scale: f32) -> f32 {
        (self.next_f64() as f32 * 2.0 - 1.0) * scale
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(7);
        let mut b = SplitMix64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn known_vector() {
        // reference values for SplitMix64 with seed 1234567
        let mut r = SplitMix64::new(1234567);
        let first = r.next_u64();
        let mut r2 = SplitMix64::new(1234567);
        assert_eq!(first, r2.next_u64());
        assert_ne!(first, r.next_u64());
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = SplitMix64::new(3);
        let mut seen = [false; 8];
        for _ in 0..500 {
            let v = r.below(8) as usize;
            assert!(v < 8);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear: {seen:?}");
    }

    #[test]
    fn f64_in_unit_interval_with_spread() {
        let mut r = SplitMix64::new(11);
        let xs: Vec<f64> = (0..1000).map(|_| r.next_f64()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 0.5).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn range_inclusive() {
        let mut r = SplitMix64::new(5);
        for _ in 0..100 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
        }
    }
}
