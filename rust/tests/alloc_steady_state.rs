//! Zero-allocation proof for the sweep hot path: after warm-up, repeated
//! [`SimWorkspace::run`] calls must not touch the heap at all — that is
//! the point of the CSR/arena rearchitecture (the seed engine allocated
//! per-node `Vec<Vec<usize>>` edges, a fresh `BinaryHeap` and a full
//! trace every cell).
//!
//! The proof is a thread-local counting `#[global_allocator]`: it counts
//! this thread's `alloc`/`realloc`/`alloc_zeroed` calls (dealloc is
//! free-side and irrelevant to "allocates nothing"), so other test
//! threads can't pollute the measurement.  This lives in its own
//! integration-test binary because a global allocator is process-wide.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

struct CountingAlloc;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(l)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(p, l, new_size)
    }

    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(l)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.with(|c| c.get())
}

use bpipe::bpipe::{pair_adjacent_layout, rebalance, sequential_layout};
use bpipe::config::paper_experiment;
use bpipe::schedule::{gpipe, interleaved, one_f_one_b, v_shaped};
use bpipe::sim::{SimOptions, SimWorkspace};

#[test]
fn steady_state_sweep_cells_allocate_nothing() {
    let e = paper_experiment(8).unwrap();
    let p = e.parallel.p;
    let m = e.parallel.num_microbatches();
    let layouts = [
        pair_adjacent_layout(p, e.cluster.n_nodes),
        sequential_layout(p, e.cluster.n_nodes),
    ];
    // every schedule family the sweep simulates, including the largest
    // (rebalanced interleaved) so warm-up reaches the high-water shape
    let scheds = [
        one_f_one_b(p, m),
        rebalance(&one_f_one_b(p, m), None),
        gpipe(p, m),
        interleaved(p, m, 2),
        rebalance(&interleaved(p, m, 2), None),
        v_shaped(p, m),
        rebalance(&v_shaped(p, m), None),
    ];
    let mut ws = SimWorkspace::new();
    let opts = SimOptions { trace: false };

    // warm-up: buffers grow to the largest shape in the working set
    for s in &scheds {
        for l in &layouts {
            ws.run(&e, s, l, opts);
        }
    }

    let before = allocs();
    let mut sink = 0.0;
    for _ in 0..3 {
        for s in &scheds {
            for l in &layouts {
                let stats = ws.run(&e, s, l, opts);
                sink += stats.makespan;
            }
        }
    }
    let after = allocs();
    assert!(sink > 0.0, "cells must actually simulate");
    assert_eq!(
        after - before,
        0,
        "steady-state sweep cells must perform zero heap allocations"
    );
}

#[test]
fn steady_state_trace_collection_reuses_its_buffer() {
    let e = paper_experiment(8).unwrap();
    let p = e.parallel.p;
    let m = e.parallel.num_microbatches();
    let layout = pair_adjacent_layout(p, e.cluster.n_nodes);
    let sched = rebalance(&interleaved(p, m, 2), None);
    let mut ws = SimWorkspace::new();
    let opts = SimOptions { trace: true };
    ws.run(&e, &sched, &layout, opts); // warm-up
    let before = allocs();
    for _ in 0..3 {
        ws.run(&e, &sched, &layout, opts);
    }
    assert_eq!(allocs() - before, 0, "trace buffer must be reused across runs");
    assert_eq!(ws.trace().len(), sched.num_ops());
}
