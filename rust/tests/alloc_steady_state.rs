//! Zero-allocation proofs for both hot paths:
//!
//! * the sweep engine — after warm-up, repeated [`SimWorkspace::run`]
//!   calls must not touch the heap at all (the point of PR 2's CSR/arena
//!   rearchitecture); and
//! * the REAL training pipeline — after the warm-up step populates the
//!   per-worker `BufferPool`, a steady-state `train --backend sim` step
//!   performs zero heap allocations **per stage worker** (the point of
//!   the buffer-donation layer: pooled outputs, by-handle stashes,
//!   bounded channels, in-place Adam).
//!
//! The proof is a thread-local counting `#[global_allocator]`: it counts
//! this thread's `alloc`/`realloc`/`alloc_zeroed` calls (dealloc is
//! free-side and irrelevant to "allocates nothing"), so other test
//! threads can't pollute the measurement.  The training probe runs one
//! stage worker ON THIS THREAD via `train_probed`, which is exactly what
//! makes its per-step allocations observable here.  This lives in its
//! own integration-test binary because a global allocator is
//! process-wide.

#[path = "support/counting_alloc.rs"]
mod counting_alloc;
use counting_alloc::allocs;

use bpipe::bpipe::{pair_adjacent_layout, rebalance, sequential_layout};
use bpipe::config::paper_experiment;
use bpipe::coordinator::{train_probed, train_probed_feeder, RebalancePlan, TrainConfig};
use bpipe::runtime::{Manifest, SimBackend};
use bpipe::schedule::{gpipe, interleaved, one_f_one_b, v_shaped};
use bpipe::sim::{SimOptions, SimWorkspace};

#[test]
fn steady_state_sweep_cells_allocate_nothing() {
    let e = paper_experiment(8).unwrap();
    let p = e.parallel.p;
    let m = e.parallel.num_microbatches();
    let layouts = [
        pair_adjacent_layout(p, e.cluster.n_nodes),
        sequential_layout(p, e.cluster.n_nodes),
    ];
    // every schedule family the sweep simulates, including the largest
    // (rebalanced interleaved) so warm-up reaches the high-water shape
    let scheds = [
        one_f_one_b(p, m),
        rebalance(&one_f_one_b(p, m), None),
        gpipe(p, m),
        interleaved(p, m, 2),
        rebalance(&interleaved(p, m, 2), None),
        v_shaped(p, m),
        rebalance(&v_shaped(p, m), None),
    ];
    let mut ws = SimWorkspace::new();
    let opts = SimOptions { trace: false, warm: false, recompute: false };

    // warm-up: buffers grow to the largest shape in the working set
    for s in &scheds {
        for l in &layouts {
            ws.run(&e, s, l, opts);
        }
    }

    let before = allocs();
    let mut sink = 0.0;
    for _ in 0..3 {
        for s in &scheds {
            for l in &layouts {
                let stats = ws.run(&e, s, l, opts);
                sink += stats.makespan;
            }
        }
    }
    let after = allocs();
    assert!(sink > 0.0, "cells must actually simulate");
    assert_eq!(
        after - before,
        0,
        "steady-state sweep cells must perform zero heap allocations"
    );
}

/// THE acceptance invariant of the buffer-lifecycle layer: a
/// steady-state training step of the real pipeline allocates NOTHING on
/// the stage-worker thread.  Stage 0 is probed on this thread — it is
/// also a BPipe evictor here (uniform derived bound), so the measured
/// path covers recv → donate-fwd → stash → evict/load through the remote
/// store → donate-bwd → in-place Adam → bounded-channel sends.
#[test]
fn steady_state_train_step_allocates_nothing_per_stage_worker() {
    let cfg = TrainConfig {
        manifest: Some(Manifest::synthetic(4, 16, 8, 2, 64, &[1, 2])),
        steps: 6,
        microbatches: 6,
        lr: 2e-3,
        seed: 7,
        rebalance: RebalancePlan::Uniform { bound: None },
        ..TrainConfig::default()
    };
    let mut per_step: Vec<(u64, u64)> = Vec::with_capacity(cfg.steps as usize);
    let mut last = 0u64;
    let r = train_probed::<SimBackend>(&cfg, 0, &mut |step| {
        let now = allocs();
        per_step.push((step, now - last));
        last = now;
    })
    .unwrap();
    assert_eq!(r.losses.len(), 6);
    assert!(r.stage_stats[0].evictions > 0, "the probed stage must actually evict");
    let (warm_step, warm) = per_step[0];
    assert_eq!(warm_step, 1);
    assert!(warm > 0, "the warm-up step is expected to populate the pool");
    for &(step, n) in &per_step[1..] {
        assert_eq!(n, 0, "steady-state step {step} performed {n} heap allocations");
    }
    // and the pool telemetry agrees: misses stopped after warm-up
    assert!(r.stage_stats[0].pool_hits > 0);
    assert!(
        r.stage_stats[0].pool_misses < r.stage_stats[0].pool_hits,
        "steady state must be hit-dominated: {} misses vs {} hits",
        r.stage_stats[0].pool_misses,
        r.stage_stats[0].pool_hits
    );
}

/// The checkpoint path rides the same invariant: with `CheckpointWriter`
/// holding the serialization scratch and borrowing the host buffers in
/// place (no `.to_vec()` staging copies), a steady-state step that ALSO
/// writes a checkpoint adds only libstd's per-syscall path→CString
/// conversions (File::create, the exists() stat, and the two renames —
/// 6 calls, none scaling with the parameter count).  Before the writer,
/// every checkpoint step re-allocated 4 parameter-sized buffers (three
/// staging vectors + the serialization buffer), which this bound
/// catches immediately.
#[test]
fn steady_state_checkpoint_step_adds_no_buffer_allocations() {
    let dir = std::env::temp_dir().join(format!("bpipe-alloc-ckpt-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = TrainConfig {
        manifest: Some(Manifest::synthetic(4, 16, 8, 2, 64, &[1, 2])),
        steps: 6,
        microbatches: 6,
        lr: 2e-3,
        seed: 7,
        rebalance: RebalancePlan::Uniform { bound: None },
        checkpoint_dir: Some(dir.clone()),
        checkpoint_every: 1,
        ..TrainConfig::default()
    };
    let mut per_step: Vec<(u64, u64)> = Vec::with_capacity(cfg.steps as usize);
    let mut last = 0u64;
    let r = train_probed::<SimBackend>(&cfg, 0, &mut |step| {
        let now = allocs();
        per_step.push((step, now - last));
        last = now;
    })
    .unwrap();
    assert_eq!(r.losses.len(), 6);
    let (warm_step, warm) = per_step[0];
    assert_eq!(warm_step, 1);
    assert!(warm > 0, "warm-up populates the pool and grows the writer scratch");
    for &(step, n) in &per_step[1..] {
        assert!(
            n <= 6,
            "checkpointing step {step} performed {n} heap allocations — the writer \
             must reuse its scratch and borrow the state buffers in place \
             (6 path→CString conversions are the libstd fs-syscall floor)"
        );
    }
    // the writer really wrote every generation it claims to
    assert!(bpipe::coordinator::CheckpointMeta::exists(&dir) || dir.join("stage0.ckpt").exists());
    let _ = std::fs::remove_dir_all(&dir);
}

/// The feeder-side twin: the LAST per-microbatch allocation was the
/// feeder building fresh token/target vectors (plus their shape vecs)
/// for every send.  With the recycle ring the end-stage workers hand
/// those tensors back after the backward, the feeder refills them in
/// place (`microbatch_into`), and a steady-state step feeds `2m`
/// microbatches with zero feeder-side heap allocations.  The first
/// steps may still allocate while the free list warms (recycled tensors
/// only start returning after the first backwards), so the pin starts
/// at step 5.
#[test]
fn steady_state_feeder_allocates_nothing_once_recycling_warms() {
    let cfg = TrainConfig {
        manifest: Some(Manifest::synthetic(4, 16, 8, 2, 64, &[1, 2])),
        steps: 8,
        microbatches: 6,
        lr: 2e-3,
        seed: 11,
        rebalance: RebalancePlan::Uniform { bound: None },
        ..TrainConfig::default()
    };
    let mut per_step: Vec<(u64, u64)> = Vec::with_capacity(cfg.steps as usize);
    let mut last = 0u64;
    let r = train_probed_feeder::<SimBackend>(&cfg, &mut |step| {
        let now = allocs();
        per_step.push((step, now - last));
        last = now;
    })
    .unwrap();
    assert_eq!(r.losses.len(), 8);
    assert!(per_step[0].1 > 0, "the first step must populate the free list");
    for &(step, n) in &per_step[4..] {
        assert_eq!(n, 0, "steady-state feeder step {step} performed {n} heap allocations");
    }
}

#[test]
fn steady_state_trace_collection_reuses_its_buffer() {
    let e = paper_experiment(8).unwrap();
    let p = e.parallel.p;
    let m = e.parallel.num_microbatches();
    let layout = pair_adjacent_layout(p, e.cluster.n_nodes);
    let sched = rebalance(&interleaved(p, m, 2), None);
    let mut ws = SimWorkspace::new();
    let opts = SimOptions { trace: true, warm: false, recompute: false };
    ws.run(&e, &sched, &layout, opts); // warm-up
    let before = allocs();
    for _ in 0..3 {
        ws.run(&e, &sched, &layout, opts);
    }
    assert_eq!(allocs() - before, 0, "trace buffer must be reused across runs");
    assert_eq!(ws.trace().len(), sched.num_ops());
}
