//! Coverage for the analyzer's whole reporting surface: every
//! [`Diagnostic`] code the three passes can emit is exercised through
//! the public API and asserted in BOTH human (`Display` /
//! `render_diagnostics`) and machine (`to_json` / `diagnostics_to_json`)
//! form, and every [`ValidationError`] variant of the structural
//! validator is pinned — triggered through `validate` where reachable,
//! constructed directly where the state machine makes it structurally
//! unreachable (earlier checks always fire first).

use std::collections::BTreeSet;

use bpipe::analysis::{
    check_bounds, check_capacity, check_linearity, check_linearity_with_caps, check_protocol,
    check_schedule, diagnostics_to_json, has_errors, render_diagnostics, ChannelCaps, Diagnostic,
    Severity,
};
use bpipe::config::paper_experiment;
use bpipe::schedule::{
    validate, Family, Op, OpKind, Placement, Schedule, ScheduleKind, StageProgram,
    ValidationError,
};
use bpipe::util::json::Json;

/// Single-stage scaffold for hand-built op sequences.
fn stage1(ops: Vec<Op>) -> Schedule {
    Schedule {
        p: 1,
        m: 8,
        chunks: 1,
        placement: Placement::Sequential,
        kind: ScheduleKind::OneFOneB,
        stage_bounds: None,
        programs: vec![StageProgram { stage: 0, ops }],
    }
}

/// Two-stage scaffold (the smallest pipeline with a real protocol).
fn stage2(ops0: Vec<Op>, ops1: Vec<Op>, m: u64) -> Schedule {
    Schedule {
        p: 2,
        m,
        chunks: 1,
        placement: Placement::Sequential,
        kind: ScheduleKind::OneFOneB,
        stage_bounds: None,
        programs: vec![
            StageProgram { stage: 0, ops: ops0 },
            StageProgram { stage: 1, ops: ops1 },
        ],
    }
}

fn codes(ds: &[Diagnostic]) -> BTreeSet<&'static str> {
    ds.iter().map(|d| d.code).collect()
}

/// Every diagnostic code the analyzer can emit, reached through the
/// public entry points — no constructor shortcuts.
#[test]
fn every_diagnostic_code_is_reachable_through_the_passes() {
    let mut reached: Vec<Diagnostic> = Vec::new();

    // pass 0 + pass 1: a dropped backward is structurally invalid,
    // starves the protocol, and leaks a handle
    let mut broken = Family::OneFOneB.build(4, 4);
    broken.programs[2].ops.pop();
    reached.extend(check_schedule(&broken, &ChannelCaps::for_run(4, 1)));

    // pass 1: out-of-order forwards on the downstream stage hit the
    // FIFO tags
    let fifo = stage2(
        vec![Op::fwd(0), Op::fwd(1), Op::bwd(0), Op::bwd(1)],
        vec![Op::fwd(1), Op::fwd(0), Op::bwd(1), Op::bwd(0)],
        2,
    );
    reached.extend(check_protocol(&fifo, &ChannelCaps::for_run(2, 1)));

    // pass 1: a duplicated loss-side backward finishes every trace but
    // strands messages in the gradient and loss rings
    let residue = stage2(
        vec![Op::fwd(0), Op::bwd(0)],
        vec![Op::fwd(0), Op::bwd(0), Op::bwd(0)],
        1,
    );
    reached.extend(check_protocol(&residue, &ChannelCaps::for_run(1, 1)));

    // pass 2: one op sequence per linearity violation
    reached.extend(check_linearity(&stage1(vec![Op::fwd(0), Op::fwd(0)])));
    reached.extend(check_linearity(&stage1(vec![Op::bwd(0)])));
    reached.extend(check_linearity(&stage1(vec![Op::fwd(0), Op::evict(0), Op::bwd(0)])));
    reached.extend(check_linearity(&stage1(vec![Op::fwd(0), Op::bwd(0), Op::bwd(0)])));
    reached.extend(check_linearity(&stage1(vec![Op::fwd(9), Op::bwd(9)])));
    reached.extend(check_linearity(&stage1(vec![Op::fwd(0), Op::evict(0)])));
    reached.extend(check_linearity_with_caps(
        &stage1(vec![Op::fwd(0), Op::fwd(1), Op::bwd(0), Op::bwd(1)]),
        &[1],
    ));

    // pass 3: a planned bound below the program's own floor is
    // statically hopeless …
    let mut tight = Family::OneFOneB.build(4, 4);
    tight.stage_bounds = Some(vec![1, 1, 1, 1]);
    reached.extend(check_bounds(&tight));

    // … and experiment 8's sequential 1F1B provably overflows HBM
    let e = paper_experiment(8).unwrap();
    let base = Family::OneFOneB.build(e.parallel.p, e.parallel.num_microbatches());
    reached.extend(check_capacity(&e, &base));

    let want: BTreeSet<&'static str> = [
        "invalid-schedule",
        "deadlock-cycle",
        "fifo-mismatch",
        "channel-residue",
        "double-stash",
        "use-uninitialized",
        "use-after-donate",
        "double-donate",
        "stash-overflow",
        "slot-out-of-range",
        "donation-leak",
        "static-bound-exceeded",
        "provably-oom",
    ]
    .into_iter()
    .collect();
    let got = codes(&reached);
    assert_eq!(got, want, "reached {got:?}, expected exactly {want:?}");

    // and both renderings carry every code
    let human = render_diagnostics(&reached);
    let json = diagnostics_to_json(&reached).to_string();
    for code in &want {
        assert!(human.contains(code), "human rendering lost {code}:\n{human}");
        assert!(json.contains(code), "json rendering lost {code}");
    }
}

/// Severity surfaces consistently: ordering, labels, human `Display`,
/// gate behavior, and machine-readable JSON (round-tripped through the
/// in-tree parser, not string-matched).
#[test]
fn diagnostics_render_consistently_in_human_and_json_form() {
    assert!(Severity::Info < Severity::Warning && Severity::Warning < Severity::Error);

    let err = Diagnostic::error("deadlock-cycle", None, "wait-for cycle: …".to_string());
    let warn = Diagnostic::warning("provably-oom", Some(3), "peak over HBM".to_string());
    assert_eq!(err.to_string(), "error[deadlock-cycle]: wait-for cycle: …");
    assert_eq!(warn.to_string(), "warning[provably-oom] stage 3: peak over HBM");

    assert!(has_errors(&[err.clone()]));
    assert!(!has_errors(&[warn.clone()]));

    // errors sort ahead of warnings in the human report
    let report = render_diagnostics(&[warn.clone(), err.clone()]);
    let e_at = report.find("error[").unwrap();
    let w_at = report.find("warning[").unwrap();
    assert!(e_at < w_at, "errors must lead the report:\n{report}");

    let parsed = Json::parse(&diagnostics_to_json(&[warn]).to_string()).unwrap();
    match parsed {
        Json::Arr(items) => {
            assert_eq!(items.len(), 1);
            match &items[0] {
                Json::Obj(fields) => {
                    assert_eq!(fields.get("severity"), Some(&Json::Str("warning".into())));
                    assert_eq!(fields.get("code"), Some(&Json::Str("provably-oom".into())));
                    assert_eq!(fields.get("stage"), Some(&Json::Num(3.0)));
                    assert!(fields.contains_key("message"));
                }
                other => panic!("expected an object, got {other:?}"),
            }
        }
        other => panic!("expected an array, got {other:?}"),
    }
}

/// Every reachable [`ValidationError`] variant, each triggered through
/// `validate` and surfaced by `check_schedule` as an `invalid-schedule`
/// diagnostic naming the variant (its `Display` is the debug form).
#[test]
fn every_reachable_validator_error_surfaces_as_invalid_schedule() {
    // WrongStageCount leaves the programs array inconsistent with `p`,
    // which the deeper passes are allowed to assume — validator only.
    let mut short = stage1(vec![Op::fwd(0), Op::bwd(0)]);
    short.p = 2;
    let err = validate(&short).expect_err("WrongStageCount");
    assert!(format!("{err}").contains("WrongStageCount"), "{err:?}");

    let cases: Vec<(&str, Schedule)> = vec![
        ("StageIdMismatch", {
            let mut s = stage1(vec![Op::fwd(0), Op::bwd(0)]);
            s.programs[0].stage = 7;
            s
        }),
        ("StageBoundsWrongLength", {
            let mut s = stage1(vec![Op::fwd(0), Op::bwd(0)]);
            s.stage_bounds = Some(vec![2, 2]);
            s
        }),
        ("DuplicateOp", stage1(vec![Op::fwd(0), Op::fwd(0), Op::bwd(0)])),
        ("MissingBwd", stage1(vec![Op::fwd(0)])),
        ("BwdBeforeFwd", stage1(vec![Op::bwd(0), Op::fwd(0)])),
        ("EvictWithoutFwd", stage1(vec![Op::fwd(0), Op::bwd(0), Op::evict(0), Op::load(0)])),
        ("LoadWithoutEvict", stage1(vec![Op::fwd(0), Op::load(0), Op::bwd(0)])),
        ("BwdWhileEvicted", stage1(vec![Op::fwd(0), Op::evict(0), Op::bwd(0)])),
        ("UnknownMicrobatch", stage1(vec![Op::fwd(99), Op::bwd(99)])),
        ("UnknownChunk", {
            stage1(vec![
                Op { kind: OpKind::Fwd, mb: 0, chunk: 1 },
                Op { kind: OpKind::Bwd, mb: 0, chunk: 1 },
            ])
        }),
        ("BoundExceeded", {
            let mut s = stage1(vec![
                Op::fwd(0),
                Op::fwd(1),
                Op::fwd(2),
                Op::bwd(0),
                Op::bwd(1),
                Op::bwd(2),
            ]);
            s.kind = ScheduleKind::BPipe { bound: 2 };
            s
        }),
        ("StageBoundExceeded", {
            let mut s = stage1(vec![
                Op::fwd(0),
                Op::fwd(1),
                Op::fwd(2),
                Op::bwd(0),
                Op::bwd(1),
                Op::bwd(2),
            ]);
            s.stage_bounds = Some(vec![2]);
            s
        }),
    ];
    for (variant, s) in cases {
        let err = validate(&s).expect_err(variant);
        assert!(
            format!("{err}").contains(variant),
            "Display of {err:?} must name {variant}"
        );
        let diags = check_schedule(&s, &ChannelCaps::for_run(s.m, s.chunks));
        let inv = diags
            .iter()
            .find(|d| d.code == "invalid-schedule")
            .unwrap_or_else(|| panic!("{variant}: no invalid-schedule in {diags:?}"));
        assert_eq!(inv.severity, Severity::Error);
        assert!(
            inv.message.contains(variant),
            "{variant} not named in {:?}",
            inv.message
        );
    }
}

/// The two variants the validator's own ordering makes structurally
/// unreachable (an earlier check always fires first): `MissingFwd` is
/// pre-empted by `BwdBeforeFwd` at the offending op, `NegativeStash` by
/// the residency checks on `Bwd`/`Evict`.  They stay in the enum as
/// defense in depth; pin their reporting shape directly.
#[test]
fn structurally_unreachable_validator_errors_still_render() {
    let missing = ValidationError::MissingFwd { stage: 1, mb: 2, chunk: 0 };
    let negative = ValidationError::NegativeStash { stage: 3, at_op: 9 };
    assert!(format!("{missing}").contains("MissingFwd"));
    assert!(format!("{negative}").contains("NegativeStash"));
    // and the wrapping `check_schedule` applies verbatim to their text
    let d = Diagnostic::error("invalid-schedule", None, missing.to_string());
    assert!(d.to_string().starts_with("error[invalid-schedule]"));
    assert!(d.to_string().contains("MissingFwd"));
}
