//! Chaos suite for the elastic fleet runtime: replica-scoped fault
//! injection against `fleet::serve`'s drain → redistribute → re-admit
//! loop.
//!
//! The headline properties:
//!
//! * **Survivors progress, nothing hangs.**  Killing a replica mid-run
//!   degrades the fleet; the dead replica's in-flight work drains back
//!   to the queue, the survivors absorb it, and the whole run still
//!   terminates with every admitted item completed.
//! * **Admission math is exact.**  `offered = admitted + shed` holds
//!   through every failure transition, and the entire serve run is
//!   deterministic per seed — two identical runs produce the identical
//!   event sequence and counters (wall-clock fields excluded).
//! * **Recovery is exact.**  With stealing off and no shedding, each
//!   replica executes exactly its own slice of the stream, so a fleet
//!   that lost and re-admitted a replica ends with final weights
//!   bit-identical to R standalone uninterrupted training runs.
//!
//! Fault plans install into a process-global registry, so every test
//! here serializes on one lock.

use std::path::PathBuf;
use std::sync::{Arc, Mutex, MutexGuard};

use bpipe::coordinator::{train, TrainConfig};
use bpipe::fleet::{serve, FleetConfig, FleetEvent, TrafficPattern};
use bpipe::runtime::{Fault, FaultPlan, FaultyBackend, Manifest, SimBackend};

type FB = FaultyBackend<SimBackend>;

static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn tmp(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("bpipe-chaos-fleet-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The same synthetic 2-virtual-stage model the recovery chaos suite
/// trains (h=16, s=8, b=2, vocab 64).
fn manifest() -> Manifest {
    Manifest::synthetic(2, 16, 8, 2, 64, &[1, 2])
}

/// Deterministic per-event signature: everything EXCEPT wall-clock
/// fields (latency, time-to-recover), which legitimately vary run to
/// run.
fn signature(events: &[FleetEvent]) -> Vec<String> {
    events
        .iter()
        .map(|e| match e {
            FleetEvent::Traffic { round, arrivals, admitted, shed, queue_len } => {
                format!("traffic r{round} a{arrivals} ad{admitted} sh{shed} q{queue_len}")
            }
            FleetEvent::CapPlan { stage, cap_bytes, bounds } => {
                format!("cap-plan s{stage} c{cap_bytes} b{bounds:?}")
            }
            FleetEvent::ReplicaFailed { round, replica, report } => {
                format!("failed r{round} rep{replica} cause={}", report.cause.label())
            }
            FleetEvent::Drain { round, replica, completed, drained } => {
                format!("drain r{round} rep{replica} c{completed} d{drained}")
            }
            FleetEvent::Degraded { round, alive, replicas } => {
                format!("degraded r{round} {alive}/{replicas}")
            }
            FleetEvent::ReplicaReadmitted { round, replica, from_step } => {
                format!("readmit r{round} rep{replica} from{from_step}")
            }
            FleetEvent::ReplicaRecovered { round, replica, .. } => {
                format!("recovered r{round} rep{replica}")
            }
            FleetEvent::Sync { round, replicas, elements } => {
                format!("sync r{round} n{replicas} e{elements}")
            }
            FleetEvent::Done { rounds, completed, shed } => {
                format!("done r{rounds} c{completed} sh{shed}")
            }
        })
        .collect()
}

fn count(events: &[FleetEvent], label: &str) -> usize {
    events.iter().filter(|e| e.label() == label).count()
}

/// Kill replica 1 mid-run under bursty traffic on a deliberately small
/// queue: survivors progress, admitted work all completes, shedding is
/// typed and conserved, the dead replica is re-admitted and recovers —
/// and the whole thing is deterministic per seed.
#[test]
fn killed_replica_degrades_then_recovers_under_bursty_load() {
    let _g = lock();
    let cfg = FleetConfig {
        replicas: 3,
        steps: 30,
        traffic: TrafficPattern::Bursty,
        rate: 8,
        queue_cap: 4,
        segment_len: 1,
        seed: 5,
        manifest: Some(manifest()),
        faults: Some(Arc::new(FaultPlan::new_scoped(
            0,
            vec![(Some(1), Fault::Crash { stage: 1, step: 2 })],
        ))),
        max_restarts: 0,
        readmit_after: 2,
        sync_every: 0,
        steal: true,
        run_dir: tmp("kill-one"),
        ..FleetConfig::default()
    };
    let out = serve::<FB>(&cfg).expect("fleet survives a replica kill");

    // conservation, and every admitted item completed despite the kill
    let s = &out.stats;
    assert_eq!(s.offered, 30);
    assert_eq!(s.offered, s.admitted + s.shed, "admission conservation");
    assert_eq!(s.completed(), s.admitted, "no admitted item lost through drain/redistribute");
    assert_eq!(out.steps_done.iter().sum::<u64>(), s.admitted);
    assert!(s.shed > 0, "arrivals at 2× drain capacity on a 4-deep queue must shed");

    // the failure transition is visible and targeted: replica 1 failed,
    // the fleet degraded, re-admitted it, and it completed a segment
    let fail_replicas: Vec<usize> = out
        .events
        .iter()
        .filter_map(|e| match e {
            FleetEvent::ReplicaFailed { replica, .. } => Some(*replica),
            _ => None,
        })
        .collect();
    assert_eq!(fail_replicas, vec![1], "exactly the scoped replica fails, exactly once");
    assert_eq!(count(&out.events, "drain"), 1);
    assert_eq!(count(&out.events, "degraded"), 1);
    assert_eq!(count(&out.events, "replica-readmitted"), 1);
    assert_eq!(count(&out.events, "replica-recovered"), 1);
    assert!(s.degraded_rounds > 0);
    assert_eq!(s.time_to_recover_s.len(), 1);
    assert!(s.p99_latency_s().is_finite());

    // survivors kept making progress while replica 1 was down
    assert!(out.steps_done[0] > 0 && out.steps_done[2] > 0);
    assert!(out.steps_done[1] > 0, "the re-admitted replica resumed and progressed");

    // determinism: the identical config replays the identical event
    // sequence and counters (wall-clock fields excluded)
    let out2 = serve::<FB>(&cfg).expect("replay");
    assert_eq!(signature(&out.events), signature(&out2.events));
    assert_eq!(out.steps_done, out2.steps_done);
    assert_eq!(out2.stats.shed, s.shed);
    let _ = std::fs::remove_dir_all(&cfg.run_dir);
}

/// With stealing off, no shedding and sync off, each replica owns a
/// fixed slice of the stream — so a fleet that crashed, drained and
/// re-admitted replica 1 must end with final weights bit-identical to
/// two standalone uninterrupted training runs (fleet recovery is exact,
/// not just "eventually converges").
#[test]
fn no_shed_fleet_weights_match_standalone_runs() {
    let _g = lock();
    let m = manifest();
    let cfg = FleetConfig {
        replicas: 2,
        steps: 8,
        traffic: TrafficPattern::Steady,
        queue_cap: 16,
        segment_len: 2,
        seed: 21,
        manifest: Some(m.clone()),
        faults: Some(Arc::new(FaultPlan::new_scoped(
            0,
            vec![(Some(1), Fault::Crash { stage: 1, step: 2 })],
        ))),
        max_restarts: 0,
        readmit_after: 1,
        sync_every: 0,
        steal: false,
        run_dir: tmp("bit-identical"),
        ..FleetConfig::default()
    };
    let out = serve::<FB>(&cfg).expect("fleet completes");
    assert_eq!(out.stats.shed, 0, "queue cap 16 at rate 4 must not shed");
    assert_eq!(out.steps_done, vec![4, 4], "id%2 homing splits 8 items evenly");
    assert_eq!(count(&out.events, "replica-failed"), 1);
    assert_eq!(count(&out.events, "replica-recovered"), 1);

    // standalone baselines: same per-replica seed, same total steps,
    // no faults, no fleet
    for r in 0..2usize {
        let base_dir = tmp(&format!("bit-identical-base{r}"));
        let base = TrainConfig {
            manifest: Some(m.clone()),
            steps: 4,
            microbatches: cfg.microbatches,
            lr: cfg.lr,
            seed: cfg.seed.wrapping_add(r as u64),
            checkpoint_dir: Some(base_dir.clone()),
            checkpoint_every: 1,
            ..TrainConfig::default()
        };
        train::<SimBackend>(&base).expect("baseline");
        let want = checkpoints(&base_dir, &m);
        let got = checkpoints(&cfg.run_dir.join(format!("replica{r}")), &m);
        for (virt, (g, w)) in got.iter().zip(want.iter()).enumerate() {
            assert_eq!(g.params, w.params, "replica {r} stage {virt} params diverged");
            assert_eq!(g.m, w.m, "replica {r} stage {virt} Adam m diverged");
            assert_eq!(g.v, w.v, "replica {r} stage {virt} Adam v diverged");
        }
        let _ = std::fs::remove_dir_all(&base_dir);
    }
    let _ = std::fs::remove_dir_all(&cfg.run_dir);
}

/// Load every virtual stage's newest checkpoint from `dir`.
fn checkpoints(dir: &std::path::Path, manifest: &Manifest) -> Vec<bpipe::coordinator::StageCheckpoint> {
    (0..manifest.spec.stages)
        .map(|virt| {
            let n = manifest.param_count(manifest.stage_kind(virt)).unwrap() as usize;
            bpipe::coordinator::StageCheckpoint::load(dir, virt, n)
                .unwrap_or_else(|e| panic!("loading stage {virt} from {dir:?}: {e}"))
        })
        .collect()
}

/// Even losing EVERY replica is survivable with re-admission on: each
/// failure drains, each replica sits out its cool-down, comes back, and
/// the full offered stream still completes.
#[test]
fn fleet_survives_every_replica_failing() {
    let _g = lock();
    let cfg = FleetConfig {
        replicas: 3,
        steps: 18,
        traffic: TrafficPattern::Steady,
        queue_cap: 32,
        segment_len: 2,
        seed: 3,
        manifest: Some(manifest()),
        faults: Some(Arc::new(FaultPlan::new_scoped(
            0,
            vec![
                (Some(0), Fault::Crash { stage: 0, step: 1 }),
                (Some(1), Fault::Crash { stage: 1, step: 2 }),
                (Some(2), Fault::Crash { stage: 0, step: 3 }),
            ],
        ))),
        max_restarts: 0,
        readmit_after: 1,
        sync_every: 0,
        steal: false,
        run_dir: tmp("kill-all"),
        ..FleetConfig::default()
    };
    let out = serve::<FB>(&cfg).expect("every replica recovers");
    assert_eq!(count(&out.events, "replica-failed"), 3, "each replica fails exactly once");
    assert_eq!(count(&out.events, "replica-recovered"), 3);
    assert_eq!(out.stats.shed, 0);
    assert_eq!(out.stats.completed(), 18);
    assert_eq!(out.steps_done, vec![6, 6, 6], "stealing off: everyone serves their own slice");
    let _ = std::fs::remove_dir_all(&cfg.run_dir);
}
