//! Chaos suite for the fault-tolerant training runtime: deterministic
//! fault injection ([`FaultPlan`] via [`FaultyBackend`]) against the
//! supervisor's checkpoint–re-plan–resume loop.
//!
//! The headline property: **recovery is exact**.  For every schedule
//! family × rebalance plan, a run crashed at step k and supervised back
//! to health produces losses AND final weights bit-identical to the
//! uninterrupted run — including when an HBM-cap fault forced a re-plan
//! onto tighter per-stage bounds mid-run (BPipe eviction is pure data
//! movement, so the re-planned trajectory is still the same
//! computation).  And the runtime never hangs: silent peers surface as
//! typed channel timeouts, infeasible re-plans and exhausted restart
//! budgets abort with a structured [`FailureReport`].
//!
//! Fault plans install into a process-global registry, so every test
//! here serializes on one lock.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use bpipe::coordinator::{
    plan_schedule, supervise, train, FailureCause, FailureReport, RebalancePlan, RecoveryEvent,
    StageCheckpoint, SuperviseConfig, SuperviseOutcome, TrainConfig,
};
use bpipe::runtime::{Fault, FaultPlan, FaultyBackend, Manifest, SimBackend};
use bpipe::schedule::Family;

type FB = FaultyBackend<SimBackend>;

static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

/// The synthetic model under test: `stages` virtual stages, h=16, s=8,
/// b=2, vocab 64 — the same shape the runtime integration suite trains.
fn cfg(stages: u64, steps: u64) -> TrainConfig {
    TrainConfig {
        manifest: Some(Manifest::synthetic(stages, 16, 8, 2, 64, &[1, 2])),
        steps,
        microbatches: 4,
        lr: 2e-3,
        seed: 7,
        checkpoint_every: 1,
        ..TrainConfig::default()
    }
}

fn tmp(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bpipe-chaos-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn scfg(train: TrainConfig, faults: FaultPlan) -> SuperviseConfig {
    SuperviseConfig {
        train,
        faults: Some(Arc::new(faults)),
        max_restarts: 3,
        recover_timeout: Some(Duration::from_millis(2000)),
        backoff_base_ms: 1,
        log: false,
    }
}

/// Load every virtual stage's newest checkpoint from `dir`.
fn checkpoints(dir: &Path, manifest: &Manifest) -> Vec<StageCheckpoint> {
    (0..manifest.spec.stages)
        .map(|virt| {
            let n = manifest.param_count(manifest.stage_kind(virt)).unwrap() as usize;
            StageCheckpoint::load(dir, virt, n)
                .unwrap_or_else(|e| panic!("loading stage {virt} from {dir:?}: {e}"))
        })
        .collect()
}

fn assert_same_weights(got: &[StageCheckpoint], want: &[StageCheckpoint]) {
    assert_eq!(got.len(), want.len());
    for (virt, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        assert_eq!(g.params, w.params, "stage {virt} params diverged");
        assert_eq!(g.m, w.m, "stage {virt} Adam m diverged");
        assert_eq!(g.v, w.v, "stage {virt} Adam v diverged");
    }
}

fn failure_causes(outcome: &SuperviseOutcome) -> Vec<FailureCause> {
    outcome
        .events
        .iter()
        .filter_map(|e| match e {
            RecoveryEvent::Failure { report, .. } => Some(report.cause),
            _ => None,
        })
        .collect()
}

fn no_divergence(outcome: &SuperviseOutcome) {
    assert!(
        !outcome
            .events
            .iter()
            .any(|e| matches!(e, RecoveryEvent::ReplayDivergence { .. })),
        "replayed steps must land bit-identically: {:?}",
        outcome.events
    );
}

/// THE chaos matrix: every family × {off, uniform, per-stage} rebalance,
/// crashed at every step k, recovers to losses and weights bit-identical
/// to the uninterrupted baseline.
#[test]
fn crash_recovery_is_bit_identical_across_families_and_plans() {
    let _g = lock();
    let steps = 3u64;

    // all five families share the 8-virtual-stage computation, so ONE
    // uninterrupted run is the baseline for every cell
    let base_dir = tmp("crash-base");
    let mut base = cfg(8, steps);
    base.checkpoint_dir = Some(base_dir.clone());
    let baseline = train::<SimBackend>(&base).unwrap();
    let manifest = base.manifest.clone().unwrap();
    let want_weights = checkpoints(&base_dir, &manifest);

    let families = [
        Family::OneFOneB,
        Family::GPipe,
        Family::Interleaved { v: 2 },
        Family::VShaped,
        Family::ZigZag { v: 4 },
    ];
    for family in families {
        let p = 8 / family.chunks();
        // natural per-stage stash high-waters → safe non-trivial bounds
        let natural: Vec<u64> = plan_schedule(family, p, 4, &RebalancePlan::Off)
            .1
            .iter()
            .map(|&c| c as u64)
            .collect();
        let peak = *natural.iter().max().unwrap();
        let mut per_stage: Vec<u64> = natural.iter().map(|&c| c.max(2)).collect();
        let peak_at = natural.iter().position(|&c| c == peak).unwrap();
        per_stage[peak_at] = (peak - 1).max(2);
        let plans = [
            RebalancePlan::Off,
            RebalancePlan::Uniform { bound: Some((peak - 1).max(2)) },
            RebalancePlan::PerStage { bounds: per_stage },
        ];
        for (pi, plan) in plans.iter().enumerate() {
            for k in 1..=steps {
                let dir = tmp(&format!("crash-{family:?}-{pi}-{k}"));
                let mut c = cfg(8, steps);
                c.family = family;
                c.rebalance = plan.clone();
                c.checkpoint_dir = Some(dir.clone());
                let crash = FaultPlan::new(7, vec![Fault::Crash { stage: p / 2, step: k }]);
                let outcome = supervise::<FB>(&scfg(c, crash))
                    .unwrap_or_else(|e| panic!("{family:?} plan {pi} k={k}: {e:#}"));

                assert_eq!(outcome.restarts, 1, "{family:?} plan {pi} k={k}");
                assert_eq!(
                    failure_causes(&outcome),
                    vec![FailureCause::InjectedCrash],
                    "{family:?} plan {pi} k={k}"
                );
                no_divergence(&outcome);
                assert_eq!(
                    outcome.losses, baseline.losses,
                    "{family:?} plan {pi} crash at k={k}: recovered losses diverged"
                );
                assert_same_weights(&checkpoints(&dir, &manifest), &want_weights);
                let _ = std::fs::remove_dir_all(&dir);
            }
        }
    }
    let _ = std::fs::remove_dir_all(&base_dir);
}

/// A literal worker `panic!` takes the poisoned-join path: the
/// supervisor classifies it, recovers, and the trajectory is exact.
#[test]
fn worker_panic_recovers_bit_identically() {
    let _g = lock();
    let baseline = train::<SimBackend>(&cfg(4, 3)).unwrap();

    let dir = tmp("panic");
    let mut c = cfg(4, 3);
    c.checkpoint_dir = Some(dir.clone());
    let faults = FaultPlan::new(7, vec![Fault::Panic { stage: 1, step: 2 }]);
    let outcome = supervise::<FB>(&scfg(c, faults)).unwrap();
    assert_eq!(outcome.restarts, 1);
    assert_eq!(failure_causes(&outcome), vec![FailureCause::WorkerPanic]);
    assert_eq!(outcome.losses, baseline.losses);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A stage that goes silent must surface as a typed channel TIMEOUT on
/// its neighbors — never a hang — and the run still recovers exactly.
#[test]
fn channel_stall_times_out_instead_of_hanging() {
    let _g = lock();
    let baseline = train::<SimBackend>(&cfg(4, 3)).unwrap();

    let dir = tmp("stall");
    let mut c = cfg(4, 3);
    c.checkpoint_dir = Some(dir.clone());
    let faults =
        FaultPlan::new(7, vec![Fault::ChannelStall { stage: 1, step: 2, stall_ms: 1500 }]);
    let mut s = scfg(c, faults);
    s.recover_timeout = Some(Duration::from_millis(250));
    let t0 = std::time::Instant::now();
    let outcome = supervise::<FB>(&s).unwrap();
    assert!(
        t0.elapsed() < Duration::from_secs(20),
        "stall recovery took {:?} — deadline detection is not working",
        t0.elapsed()
    );
    assert_eq!(outcome.restarts, 1);
    assert!(
        matches!(failure_causes(&outcome)[..], [FailureCause::ChannelTimeout { .. }]),
        "silence must classify as a timeout, got {:?}",
        failure_causes(&outcome)
    );
    assert_eq!(outcome.losses, baseline.losses);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The feeder has no backend; its stall hook lives in the pipeline's
/// feed loop and must trip the first stage's receive deadline.
#[test]
fn feeder_stall_times_out_instead_of_hanging() {
    let _g = lock();
    let baseline = train::<SimBackend>(&cfg(4, 3)).unwrap();

    let dir = tmp("feeder-stall");
    let mut c = cfg(4, 3);
    c.checkpoint_dir = Some(dir.clone());
    let faults = FaultPlan::new(7, vec![Fault::FeederStall { step: 2, stall_ms: 1500 }]);
    let mut s = scfg(c, faults);
    s.recover_timeout = Some(Duration::from_millis(250));
    let outcome = supervise::<FB>(&s).unwrap();
    assert_eq!(outcome.restarts, 1);
    assert!(
        matches!(failure_causes(&outcome)[..], [FailureCause::ChannelTimeout { .. }]),
        "got {:?}",
        failure_causes(&outcome)
    );
    assert_eq!(outcome.losses, baseline.losses);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Transient execute failures within the retry budget are absorbed IN
/// PLACE: zero restarts, the retries counted, numerics untouched.
#[test]
fn transient_exec_failures_retry_in_place() {
    let _g = lock();
    let baseline = train::<SimBackend>(&cfg(4, 3)).unwrap();

    let dir = tmp("transient");
    let mut c = cfg(4, 3);
    c.checkpoint_dir = Some(dir.clone());
    c.retry_budget = 3;
    c.retry_backoff_ms = 1;
    let faults =
        FaultPlan::new(7, vec![Fault::TransientExec { stage: 1, step: 2, failures: 2 }]);
    let outcome = supervise::<FB>(&scfg(c, faults)).unwrap();
    assert_eq!(outcome.restarts, 0, "transients within budget must not restart");
    assert_eq!(outcome.retried_executes, 2);
    assert!(failure_causes(&outcome).is_empty());
    assert_eq!(outcome.losses, baseline.losses);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Transients beyond the in-place budget escalate to a restart — and the
/// consumed budget means the replay gets through.
#[test]
fn transient_exec_budget_exhaustion_escalates_to_restart() {
    let _g = lock();
    let baseline = train::<SimBackend>(&cfg(4, 3)).unwrap();

    let dir = tmp("transient-exhaust");
    let mut c = cfg(4, 3);
    c.checkpoint_dir = Some(dir.clone());
    c.retry_budget = 1;
    c.retry_backoff_ms = 1;
    let faults =
        FaultPlan::new(7, vec![Fault::TransientExec { stage: 1, step: 2, failures: 3 }]);
    let outcome = supervise::<FB>(&scfg(c, faults)).unwrap();
    assert_eq!(outcome.restarts, 1);
    assert_eq!(failure_causes(&outcome), vec![FailureCause::ExecRetriesExhausted]);
    assert_eq!(outcome.losses, baseline.losses);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A mid-run HBM cap reduction triggers a RE-PLAN: the supervisor
/// derives tighter per-stage bounds that fit the surviving capacity,
/// the static analyzer accepts them, and the resumed (rebalanced) run
/// still matches the baseline bit for bit.
#[test]
fn hbm_cap_reduction_replans_and_stays_bit_identical() {
    let _g = lock();
    // small activations so the arithmetic is exact: h=8, s=4, b=1 →
    // a mid-stage stash entry is 1×4×8×4 = 128 B; a 256 B cap fits 2
    let mk = || TrainConfig {
        manifest: Some(Manifest::synthetic(4, 8, 4, 1, 64, &[1, 2])),
        steps: 3,
        microbatches: 6,
        lr: 2e-3,
        seed: 7,
        checkpoint_every: 1,
        ..TrainConfig::default()
    };
    let baseline = train::<SimBackend>(&mk()).unwrap();

    let dir = tmp("hbm");
    let mut c = mk();
    c.checkpoint_dir = Some(dir.clone());
    let faults =
        FaultPlan::new(7, vec![Fault::HbmCap { stage: 1, step: 2, cap_bytes: 256 }]);
    let outcome = supervise::<FB>(&scfg(c, faults)).unwrap();

    assert_eq!(outcome.restarts, 1);
    assert_eq!(
        failure_causes(&outcome),
        vec![FailureCause::HbmPressure { cap_bytes: 256 }]
    );
    let replan = outcome
        .events
        .iter()
        .find_map(|e| match e {
            RecoveryEvent::Replan { stage, cap_bytes, bounds, accepted } => {
                Some((*stage, *cap_bytes, bounds.clone(), *accepted))
            }
            _ => None,
        })
        .expect("an HBM fault must produce a re-plan event");
    assert_eq!(replan.0, 1);
    assert_eq!(replan.1, 256);
    assert!(replan.3, "the analyzer must accept the derived plan");
    assert_eq!(replan.2[1], 2, "the pressured stage is capped at what fits: {:?}", replan.2);
    // the resumed run actually honors the tighter bound…
    assert!(
        outcome.result.stage_stats[1].stash_high_water <= 2,
        "stage 1 high-water {} exceeds the re-planned bound",
        outcome.result.stage_stats[1].stash_high_water
    );
    // …and rebalancing under pressure never changes the computation
    no_divergence(&outcome);
    assert_eq!(outcome.losses, baseline.losses, "re-planned run diverged from baseline");
    let _ = std::fs::remove_dir_all(&dir);
}

/// When the surviving capacity can't hold even the BPipe floor of two
/// stash entries, there is no feasible plan: the supervisor aborts with
/// a structured report (nonzero-exit territory), it does not retry or
/// hang.
#[test]
fn infeasible_hbm_cap_aborts_with_structured_report() {
    let _g = lock();
    let dir = tmp("hbm-infeasible");
    let mut c = TrainConfig {
        manifest: Some(Manifest::synthetic(4, 8, 4, 1, 64, &[1, 2])),
        steps: 3,
        microbatches: 6,
        lr: 2e-3,
        seed: 7,
        checkpoint_every: 1,
        ..TrainConfig::default()
    };
    c.checkpoint_dir = Some(dir.clone());
    let faults =
        FaultPlan::new(7, vec![Fault::HbmCap { stage: 1, step: 2, cap_bytes: 100 }]);
    let err = supervise::<FB>(&scfg(c, faults)).expect_err("100 B fits < 2 entries");
    let report = err
        .chain()
        .find_map(|e| e.downcast_ref::<FailureReport>())
        .expect("terminal aborts carry a FailureReport");
    assert_eq!(report.cause, FailureCause::NoFeasiblePlan);
    assert!(report.detail.contains("floor of 2"), "{}", report.detail);
    let _ = std::fs::remove_dir_all(&dir);
}

/// An exhausted restart budget is the other terminal condition: the
/// abort names the LAST failure and the budget that ran out.
#[test]
fn exhausted_restart_budget_aborts() {
    let _g = lock();
    let dir = tmp("budget");
    let mut c = cfg(4, 3);
    c.checkpoint_dir = Some(dir.clone());
    let faults = FaultPlan::new(7, vec![Fault::Crash { stage: 1, step: 1 }]);
    let mut s = scfg(c, faults);
    s.max_restarts = 0;
    let err = supervise::<FB>(&s).expect_err("no restarts allowed");
    let report = err
        .chain()
        .find_map(|e| e.downcast_ref::<FailureReport>())
        .expect("terminal aborts carry a FailureReport");
    assert_eq!(report.cause, FailureCause::RestartsExhausted);
    assert!(report.detail.contains("injected"), "{}", report.detail);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Two faults in one plan: the supervisor survives a crash AND a later
/// stall in the same run, one restart each, exact to the baseline.
#[test]
fn sequential_faults_recover_one_restart_each() {
    let _g = lock();
    let baseline = train::<SimBackend>(&cfg(4, 4)).unwrap();

    let dir = tmp("sequential");
    let mut c = cfg(4, 4);
    c.checkpoint_dir = Some(dir.clone());
    let faults = FaultPlan::new(
        7,
        vec![
            Fault::Crash { stage: 2, step: 2 },
            Fault::ChannelStall { stage: 1, step: 3, stall_ms: 1200 },
        ],
    );
    let mut s = scfg(c, faults);
    s.recover_timeout = Some(Duration::from_millis(250));
    let outcome = supervise::<FB>(&s).unwrap();
    assert_eq!(outcome.restarts, 2);
    let causes = failure_causes(&outcome);
    assert_eq!(causes.len(), 2, "{causes:?}");
    assert_eq!(causes[0], FailureCause::InjectedCrash);
    assert!(matches!(causes[1], FailureCause::ChannelTimeout { .. }), "{causes:?}");
    assert_eq!(outcome.losses, baseline.losses);
    // recovery telemetry: every restart closed a time-to-recover window
    assert_eq!(outcome.time_to_recover_s.len(), 2);
    assert!(outcome.time_to_recover_s.iter().all(|&t| t >= 0.0));
    assert!(outcome.steps_lost >= 1, "a crash at step 2 replays ≥ 1 step");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Recovery events render as grep-able structured lines — the contract
/// the CI chaos leg's log artifact relies on.
#[test]
fn recovery_log_lines_are_structured() {
    let _g = lock();
    let dir = tmp("log-lines");
    let mut c = cfg(4, 3);
    c.checkpoint_dir = Some(dir.clone());
    let faults = FaultPlan::new(7, vec![Fault::Crash { stage: 1, step: 2 }]);
    let outcome = supervise::<FB>(&scfg(c, faults)).unwrap();
    assert!(!outcome.events.is_empty());
    for ev in &outcome.events {
        let line = ev.to_string();
        assert!(line.starts_with("[bpipe-recover] event="), "{line}");
    }
    assert!(
        outcome.events.iter().any(|e| matches!(e, RecoveryEvent::Resume { .. })),
        "a recovered run logs its resume"
    );
    assert!(
        matches!(outcome.events.last(), Some(RecoveryEvent::Recovered { .. })),
        "the last event of a successful run is `recovered`"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
