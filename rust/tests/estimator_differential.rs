//! Differential test: the paper's §4 Eq. 3/Eq. 4 analytic estimator
//! against the DES, evaluated **on synthesized schedules** (ISSUE 8
//! satellite).  The estimator assumes a perfect 1F1B pipeline with free
//! communication, so on any schedule the synthesizer emits it must be an
//! *upper bound* on the DES MFU — and the gap (est/DES ratio) is pinned
//! per scenario so a regression in either side (estimator algebra, DES
//! timing, or the synthesizer's choice of schedule) moves a number a
//! human can read.
//!
//! All pinned values are mirror-derived (validated Python port of the
//! cost model + DES + synthesizer, exact same arithmetic) for paper
//! experiment 8 (GPT-3 96B, p=8, m=64, pair-adjacent layout).
//! Makespans pin at 1e-9 relative; est/DES ratios at 1e-3 absolute
//! (they were derived to six decimals).

use bpipe::bpipe::pair_adjacent_layout;
use bpipe::config::{paper_experiment, ExperimentConfig};
use bpipe::estimator::model_mfu_from_stage;
use bpipe::model::memory::MemoryModel;
use bpipe::schedule::{one_f_one_b, synthesize, Schedule};
use bpipe::sim::{CostModel, SimOptions, SimWorkspace};

fn assert_close(name: &str, got: f64, want: f64) {
    let rel = ((got - want) / want).abs();
    assert!(rel < 1e-9, "{name}: got {got:.15}, pinned {want:.15} (rel {rel:.2e})");
}

/// Byte caps that make `stash_count_caps` recover `counts` exactly.
fn caps_for_counts(e: &ExperimentConfig, counts: &[u64]) -> Vec<u64> {
    let mm = MemoryModel::new(e);
    let act = mm.activation_bytes_per_microbatch(0);
    counts
        .iter()
        .enumerate()
        .map(|(s, &c)| mm.weight_opt_bytes(s as u64) + e.cluster.reserved_bytes + c * act)
        .collect()
}

fn des_run(e: &ExperimentConfig, s: &Schedule, ws: &mut SimWorkspace) -> (f64, f64) {
    let layout = pair_adjacent_layout(e.parallel.p, e.cluster.n_nodes);
    let stats = ws.run(e, s, &layout, SimOptions { trace: false, warm: false, recompute: false });
    assert_eq!(stats.oom_stage, None);
    (stats.makespan, stats.mfu)
}

/// The Eq. 3 whole-model estimate from the cost model's own single-stage
/// MFU — pinned so the estimator and cost model can't drift silently.
#[test]
fn eq3_estimate_is_pinned_for_experiment_8() {
    let e = paper_experiment(8).unwrap();
    let est = model_mfu_from_stage(&e, CostModel::new(&e).single_stage_mfu());
    assert_close("Eq.3 estimate", est, 0.5034275974509936);
}

#[test]
fn estimator_upper_bounds_des_on_synthesized_schedules() {
    let e = paper_experiment(8).unwrap();
    let m = e.parallel.num_microbatches();
    let cost = CostModel::new(&e);
    let est = model_mfu_from_stage(&e, cost.single_stage_mfu());
    let mut ws = SimWorkspace::new();

    // (scenario, per-stage stash budgets, pinned DES makespan, pinned
    // est/DES MFU ratio) — tighter budgets starve the warmup, so the
    // estimator's idealized-1F1B assumption overshoots by more
    let scenarios: [(&str, Vec<u64>, f64, f64); 4] = [
        ("uniform-2", vec![2; 8], 114.91382009373845, 3.696269),
        ("uniform-3", vec![3; 8], 112.1340818046157, 3.606857),
        ("tight-72GiB", vec![4; 8], 84.54787050101113, 2.719531),
        ("capacity-shaped", vec![5, 6, 6, 5, 4, 3, 2, 2], 83.23416886042044, 2.677275),
    ];

    for (name, counts, pinned_makespan, pinned_ratio) in scenarios {
        let s = synthesize(8, m, &caps_for_counts(&e, &counts), &cost);
        let (makespan, mfu) = des_run(&e, &s, &mut ws);
        assert_close(name, makespan, pinned_makespan);
        assert!(
            est >= mfu,
            "{name}: Eq.3 estimate {est} must upper-bound DES MFU {mfu}"
        );
        let ratio = est / mfu;
        assert!(
            (ratio - pinned_ratio).abs() < 1e-3,
            "{name}: est/DES ratio {ratio:.6}, pinned {pinned_ratio:.6}"
        );
    }
}

/// Baseline for reading the ratios above: on plain 1F1B — the schedule
/// the estimator actually models — the gap is ~3.4%, all of it the
/// communication/imbalance the analytic form ignores.
#[test]
fn estimator_gap_on_plain_1f1b_is_small() {
    let e = paper_experiment(8).unwrap();
    let m = e.parallel.num_microbatches();
    let est = model_mfu_from_stage(&e, CostModel::new(&e).single_stage_mfu());
    let mut ws = SimWorkspace::new();
    let (_, mfu) = des_run(&e, &one_f_one_b(8, m), &mut ws);
    assert!(est >= mfu, "upper bound must hold on 1F1B: {est} vs {mfu}");
    let ratio = est / mfu;
    assert!((ratio - 1.034297).abs() < 1e-3, "1F1B est/DES ratio {ratio:.6}");
}
