//! Engine-equivalence goldens: the workspace/CSR engine must produce the
//! same numbers as the reference implementation for every scenario ×
//! layout cell of exp (8) — `makespan`, `load_stall`, `transfer_bytes`,
//! `mem_high_water` and `stash_high_water` are pinned here (integer
//! fields exactly, float fields to 1e-9 relative).  The values encode
//! the conservative memory tie-break (allocations before frees at equal
//! timestamps), so a regression in either the CSR dependency build, the
//! FCFS link arbitration, the zig-zag dataflow derivation or the
//! timeline accounting fails loudly.  All 15 ranking scenarios are
//! covered — including the W-shaped (zig-zag v=4) placement and the
//! per-stage capacity-bounds rebalance — on both layouts (30 cells).
//!
//! A second test runs all 30 cells twice through ONE workspace and
//! demands bit-identical output — the arena reset must be complete.

use bpipe::bpipe::{pair_adjacent_layout, sequential_layout, Layout};
use bpipe::config::paper_experiment;
use bpipe::schedule::Schedule;
use bpipe::sim::{scenario_specs, simulate, SimOptions, SimWorkspace};

struct Golden {
    scenario: &'static str,
    layout: &'static str,
    makespan: f64,
    load_stall: f64,
    transfer_bytes: u64,
    mem_high_water: [u64; 8],
    stash_high_water: [i64; 8],
}

/// Pinned reference outputs for exp (8), v = 2 (generated from the
/// reference engine; see the module doc).
static GOLDENS: [Golden; 30] = [
    Golden {
        scenario: "1F1B",
        layout: "pair-adjacent",
        makespan: 32.15541465524464,
        load_stall: 0.0,
        transfer_bytes: 0,
        mem_high_water: [90476191488, 84607835904, 81131806464, 77655777024, 74179747584, 70703718144, 67227688704, 66052152576],
        stash_high_water: [8, 7, 6, 5, 4, 3, 2, 1],
    },
    Golden {
        scenario: "1F1B",
        layout: "sequential",
        makespan: 32.15541465524464,
        load_stall: 0.0,
        transfer_bytes: 0,
        mem_high_water: [90476191488, 84607835904, 81131806464, 77655777024, 74179747584, 70703718144, 67227688704, 66052152576],
        stash_high_water: [8, 7, 6, 5, 4, 3, 2, 1],
    },
    Golden {
        scenario: "1F1B+rebalance",
        layout: "pair-adjacent",
        makespan: 32.15541465524464,
        load_stall: 0.0,
        transfer_bytes: 1230514421760,
        mem_high_water: [83524132608, 81131806464, 81131806464, 77655777024, 74179747584, 77655777024, 74179747584, 79956270336],
        stash_high_water: [6, 6, 6, 5, 4, 5, 4, 5],
    },
    Golden {
        scenario: "1F1B+rebalance",
        layout: "sequential",
        makespan: 42.028142066304845,
        load_stall: 11.61674773849278,
        transfer_bytes: 1230514421760,
        mem_high_water: [90476191488, 84607835904, 81131806464, 77655777024, 74179747584, 77655777024, 77655777024, 76480240896],
        stash_high_water: [8, 7, 6, 5, 4, 5, 5, 4],
    },
    Golden {
        scenario: "1F1B+stage-bounds",
        layout: "pair-adjacent",
        makespan: 32.15541465524464,
        load_stall: 0.0,
        transfer_bytes: 813390888960,
        mem_high_water: [83524132608, 84607835904, 81131806464, 77655777024, 74179747584, 70703718144, 70703718144, 79956270336],
        stash_high_water: [6, 7, 6, 5, 4, 3, 3, 5],
    },
    Golden {
        scenario: "1F1B+stage-bounds",
        layout: "sequential",
        makespan: 41.74556310759805,
        load_stall: 11.327325617849485,
        transfer_bytes: 813390888960,
        mem_high_water: [87000162048, 84607835904, 81131806464, 77655777024, 74179747584, 70703718144, 74179747584, 76480240896],
        stash_high_water: [7, 7, 6, 5, 4, 3, 4, 4],
    },
    Golden {
        scenario: "GPipe",
        layout: "pair-adjacent",
        makespan: 32.1554146552447,
        load_stall: 0.0,
        transfer_bytes: 0,
        mem_high_water: [285133840128, 282741513984, 282741513984, 282741513984, 282741513984, 282741513984, 282741513984, 285042007296],
        stash_high_water: [64, 64, 64, 64, 64, 64, 64, 64],
    },
    Golden {
        scenario: "GPipe",
        layout: "sequential",
        makespan: 32.1554146552447,
        load_stall: 0.0,
        transfer_bytes: 0,
        mem_high_water: [285133840128, 282741513984, 282741513984, 282741513984, 282741513984, 282741513984, 282741513984, 285042007296],
        stash_high_water: [64, 64, 64, 64, 64, 64, 64, 64],
    },
    Golden {
        scenario: "GPipe+rebalance",
        layout: "pair-adjacent",
        makespan: 32.1554146552447,
        load_stall: 0.0,
        transfer_bytes: 0,
        mem_high_water: [285133840128, 282741513984, 282741513984, 282741513984, 282741513984, 282741513984, 282741513984, 285042007296],
        stash_high_water: [64, 64, 64, 64, 64, 64, 64, 64],
    },
    Golden {
        scenario: "GPipe+rebalance",
        layout: "sequential",
        makespan: 32.1554146552447,
        load_stall: 0.0,
        transfer_bytes: 0,
        mem_high_water: [285133840128, 282741513984, 282741513984, 282741513984, 282741513984, 282741513984, 282741513984, 285042007296],
        stash_high_water: [64, 64, 64, 64, 64, 64, 64, 64],
    },
    Golden {
        scenario: "GPipe+stage-bounds",
        layout: "pair-adjacent",
        makespan: 32.1554146552447,
        load_stall: 0.0,
        transfer_bytes: 3239659438080,
        mem_high_water: [285133840128, 282741513984, 282741513984, 282741513984, 286217543424, 286217543424, 286217543424, 288518036736],
        stash_high_water: [64, 64, 64, 64, 65, 65, 65, 65],
    },
    Golden {
        scenario: "GPipe+stage-bounds",
        layout: "sequential",
        makespan: 42.691744137953194,
        load_stall: 10.5363294827085,
        transfer_bytes: 3239659438080,
        mem_high_water: [285133840128, 282741513984, 282741513984, 286217543424, 286217543424, 289693572864, 293169602304, 305898183936],
        stash_high_water: [64, 64, 64, 65, 65, 66, 67, 70],
    },
    Golden {
        scenario: "interleaved",
        layout: "pair-adjacent",
        makespan: 30.622813512848893,
        load_stall: 0.0,
        transfer_bytes: 0,
        mem_high_water: [102642294528, 96773938944, 93297909504, 89821880064, 86345850624, 82869821184, 79393791744, 78218255616],
        stash_high_water: [23, 21, 19, 17, 15, 13, 11, 9],
    },
    Golden {
        scenario: "interleaved",
        layout: "sequential",
        makespan: 30.622813512848893,
        load_stall: 0.0,
        transfer_bytes: 0,
        mem_high_water: [102642294528, 96773938944, 93297909504, 89821880064, 86345850624, 82869821184, 79393791744, 78218255616],
        stash_high_water: [23, 21, 19, 17, 15, 13, 11, 9],
    },
    Golden {
        scenario: "interleaved+rebalance",
        layout: "pair-adjacent",
        makespan: 30.622813512848893,
        load_stall: 0.0,
        transfer_bytes: 1557261189120,
        mem_high_water: [92214206208, 89821880064, 89821880064, 89821880064, 89821880064, 89821880064, 89821880064, 92122373376],
        stash_high_water: [17, 17, 17, 17, 17, 17, 17, 17],
    },
    Golden {
        scenario: "interleaved+rebalance",
        layout: "sequential",
        makespan: 38.872764214860325,
        load_stall: 25.253041431191303,
        transfer_bytes: 1557261189120,
        mem_high_water: [99166265088, 96773938944, 93297909504, 91559894784, 88083865344, 88083865344, 89821880064, 90384358656],
        stash_high_water: [21, 21, 19, 18, 16, 16, 17, 16],
    },
    Golden {
        scenario: "interleaved+stage-bounds",
        layout: "pair-adjacent",
        makespan: 30.622813512848893,
        load_stall: 0.0,
        transfer_bytes: 2002192957440,
        mem_high_water: [85262147328, 84607835904, 84607835904, 88083865344, 91559894784, 95035924224, 95035924224, 99074432256],
        stash_high_water: [13, 14, 14, 16, 18, 20, 20, 21],
    },
    Golden {
        scenario: "interleaved+stage-bounds",
        layout: "sequential",
        makespan: 40.01140429639013,
        load_stall: 22.343834273882557,
        transfer_bytes: 2002192957440,
        mem_high_water: [93952220928, 91559894784, 89821880064, 93297909504, 91559894784, 93297909504, 93297909504, 97336417536],
        stash_high_water: [18, 18, 17, 19, 18, 19, 19, 20],
    },
    Golden {
        scenario: "V-shaped",
        layout: "pair-adjacent",
        makespan: 31.089752762057778,
        load_stall: 0.0,
        transfer_bytes: 0,
        mem_high_water: [92214206208, 89821880064, 89821880064, 89821880064, 89821880064, 89821880064, 89821880064, 92122373376],
        stash_high_water: [17, 17, 17, 17, 17, 17, 17, 17],
    },
    Golden {
        scenario: "V-shaped",
        layout: "sequential",
        makespan: 31.089752762057778,
        load_stall: 0.0,
        transfer_bytes: 0,
        mem_high_water: [92214206208, 89821880064, 89821880064, 89821880064, 89821880064, 89821880064, 89821880064, 92122373376],
        stash_high_water: [17, 17, 17, 17, 17, 17, 17, 17],
    },
    Golden {
        scenario: "V-shaped+rebalance",
        layout: "pair-adjacent",
        makespan: 31.089752762057778,
        load_stall: 0.0,
        transfer_bytes: 0,
        mem_high_water: [92214206208, 89821880064, 89821880064, 89821880064, 89821880064, 89821880064, 89821880064, 92122373376],
        stash_high_water: [17, 17, 17, 17, 17, 17, 17, 17],
    },
    Golden {
        scenario: "V-shaped+rebalance",
        layout: "sequential",
        makespan: 31.089752762057778,
        load_stall: 0.0,
        transfer_bytes: 0,
        mem_high_water: [92214206208, 89821880064, 89821880064, 89821880064, 89821880064, 89821880064, 89821880064, 92122373376],
        stash_high_water: [17, 17, 17, 17, 17, 17, 17, 17],
    },
    Golden {
        scenario: "V-shaped+stage-bounds",
        layout: "pair-adjacent",
        makespan: 31.089752762057778,
        load_stall: 0.0,
        transfer_bytes: 3156234731520,
        mem_high_water: [93952220928, 91559894784, 91559894784, 91559894784, 91559894784, 91559894784, 91559894784, 93860388096],
        stash_high_water: [18, 18, 18, 18, 18, 18, 18, 18],
    },
    Golden {
        scenario: "V-shaped+stage-bounds",
        layout: "sequential",
        makespan: 40.88502166459234,
        load_stall: 10.862788791126235,
        transfer_bytes: 3156234731520,
        mem_high_water: [97428250368, 93297909504, 93297909504, 95035924224, 93297909504, 95035924224, 93297909504, 99074432256],
        stash_high_water: [20, 19, 19, 20, 19, 20, 19, 21],
    },
    Golden {
        scenario: "W-shaped",
        layout: "pair-adjacent",
        makespan: 30.023811671977107,
        load_stall: 0.0,
        transfer_bytes: 0,
        mem_high_water: [120022441728, 117630115584, 117630115584, 117630115584, 117630115584, 117630115584, 117630115584, 119930608896],
        stash_high_water: [66, 66, 66, 66, 66, 66, 66, 66],
    },
    Golden {
        scenario: "W-shaped",
        layout: "sequential",
        makespan: 30.023811671977107,
        load_stall: 0.0,
        transfer_bytes: 0,
        mem_high_water: [120022441728, 117630115584, 117630115584, 117630115584, 117630115584, 117630115584, 117630115584, 119930608896],
        stash_high_water: [66, 66, 66, 66, 66, 66, 66, 66],
    },
    Golden {
        scenario: "W-shaped+rebalance",
        layout: "pair-adjacent",
        makespan: 30.023811671977107,
        load_stall: 0.0,
        transfer_bytes: 0,
        mem_high_water: [120022441728, 117630115584, 117630115584, 117630115584, 117630115584, 117630115584, 117630115584, 119930608896],
        stash_high_water: [66, 66, 66, 66, 66, 66, 66, 66],
    },
    Golden {
        scenario: "W-shaped+rebalance",
        layout: "sequential",
        makespan: 30.023811671977107,
        load_stall: 0.0,
        transfer_bytes: 0,
        mem_high_water: [120022441728, 117630115584, 117630115584, 117630115584, 117630115584, 117630115584, 117630115584, 119930608896],
        stash_high_water: [66, 66, 66, 66, 66, 66, 66, 66],
    },
    Golden {
        scenario: "W-shaped+stage-bounds",
        layout: "pair-adjacent",
        makespan: 30.023811671977107,
        load_stall: 0.0,
        transfer_bytes: 3180566937600,
        mem_high_water: [120891449088, 118499122944, 118499122944, 118499122944, 118499122944, 118499122944, 118499122944, 120799616256],
        stash_high_water: [67, 67, 67, 67, 67, 67, 67, 67],
    },
    Golden {
        scenario: "W-shaped+stage-bounds",
        layout: "sequential",
        makespan: 40.80997349202363,
        load_stall: 16.19297814264887,
        transfer_bytes: 3180566937600,
        mem_high_water: [127843507968, 123713167104, 123713167104, 124582174464, 120237137664, 121106145024, 120237137664, 125144653056],
        stash_high_water: [75, 73, 73, 74, 69, 70, 69, 72],
    },
];

fn layout_of(name: &str, p: u64, n_nodes: u64) -> Layout {
    match name {
        "pair-adjacent" => pair_adjacent_layout(p, n_nodes),
        "sequential" => sequential_layout(p, n_nodes),
        other => panic!("unknown layout {other}"),
    }
}

/// All 30 (schedule, layout, golden) cells, built through the SAME
/// `scenario_specs` the sweep runs — a renamed label or changed
/// generator composition in the production grid fails the lookup here
/// instead of silently testing a stale hand-rolled mapping.  Per-stage
/// scenarios derive their capacity bounds from the experiment via
/// `build_for`, exactly as the sweep worker does.
fn golden_cells(e: &bpipe::config::ExperimentConfig) -> Vec<(&'static Golden, Schedule, Layout)> {
    let p = e.parallel.p;
    let n_nodes = e.cluster.n_nodes;
    let mut cells = Vec::new();
    for spec in scenario_specs(2) {
        for layout_name in ["pair-adjacent", "sequential"] {
            let g = GOLDENS
                .iter()
                .find(|g| g.scenario == spec.name() && g.layout == layout_name)
                .unwrap_or_else(|| panic!("no golden for {} / {layout_name}", spec.name()));
            cells.push((g, spec.build_for(e), layout_of(layout_name, p, n_nodes)));
        }
    }
    assert_eq!(cells.len(), GOLDENS.len(), "every golden must be exercised");
    cells
}

fn assert_close(got: f64, want: f64, what: &str, cell: &str) {
    let tol = 1e-9 * want.abs().max(1e-9);
    assert!(
        (got - want).abs() <= tol,
        "{cell}: {what} {got:?} != golden {want:?}"
    );
}

#[test]
fn engine_matches_goldens_across_all_scenarios_and_layouts() {
    let e = paper_experiment(8).unwrap();
    for (g, schedule, layout) in golden_cells(&e) {
        let cell = format!("{} / {}", g.scenario, g.layout);
        let r = simulate(&e, &schedule, &layout);
        assert_close(r.makespan, g.makespan, "makespan", &cell);
        assert_close(r.load_stall, g.load_stall, "load_stall", &cell);
        assert_eq!(r.transfer_bytes, g.transfer_bytes, "{cell}: transfer_bytes");
        assert_eq!(&r.mem_high_water[..], &g.mem_high_water[..], "{cell}: mem_high_water");
        assert_eq!(&r.stash_high_water[..], &g.stash_high_water[..], "{cell}: stash_high_water");
    }
}

/// Cross-validation of the analyzer's pass 3 against the DES: on every
/// golden cell the closed-form bracket `[lo, hi]` must contain the
/// simulated stash peak with NO slack tuning, and on the
/// contention-free pair-adjacent layout the point predictor `pred` must
/// match the DES peak exactly or undershoot by exactly the one
/// documented in-flight transient (the stash accepted while the
/// partner's own slot is still draining).
#[test]
fn static_bounds_bracket_the_simulated_peaks_on_every_golden_cell() {
    let e = paper_experiment(8).unwrap();
    for (g, schedule, layout) in golden_cells(&e) {
        let cell = format!("{} / {}", g.scenario, g.layout);
        let r = simulate(&e, &schedule, &layout);
        let est = bpipe::analysis::static_bounds(&schedule);
        assert_eq!(est.len() as u64, schedule.p);
        for b in &est {
            let des = r.stash_high_water[b.stage as usize];
            assert!(
                b.lo <= des,
                "{cell} stage {}: static lo {} exceeds DES peak {des}",
                b.stage,
                b.lo
            );
            assert!(
                des <= b.hi,
                "{cell} stage {}: DES peak {des} escapes static hi {}",
                b.stage,
                b.hi
            );
            if g.layout == "pair-adjacent" {
                let slack = des - b.pred;
                assert!(
                    slack == 0 || slack == 1,
                    "{cell} stage {}: DES peak {des} vs pred {} — transient must be 0 or +1",
                    b.stage,
                    b.pred
                );
            }
        }
    }
}

/// ISSUE 8 golden: the schedule `synthesize` finds for experiment 8
/// under a uniform tight per-stage cap of 90% HBM (72 GiB — every one
/// of the 30 family cells above peaks ABOVE this cap, so the
/// synthesized cell is the only feasible one).  Pins the winner's
/// shape (a pure-compute warmup-depth schedule, W = [3,3,3,2,2,2,1,0])
/// and its full DES profile, mirror-derived at 1e-9 relative for
/// floats and exactly for integers.
#[test]
fn synthesized_tight_cap_winner_matches_golden() {
    use bpipe::schedule::{synthesize, OpKind, Placement, ScheduleKind};
    use bpipe::sim::CostModel;

    let mut e = paper_experiment(8).unwrap();
    let cap = e.cluster.hbm_bytes / 10 * 9;
    assert_eq!(cap, 77_309_411_328, "tight cap definition drifted");
    e.cluster.hbm_bytes = cap;
    let m = e.parallel.num_microbatches();
    let s = synthesize(8, m, &vec![cap; 8], &CostModel::new(&e));

    // shape: single-chunk, sequential placement, budgets baked in as
    // stage bounds, 64 Fwd + 64 Bwd per stage and nothing else
    assert_eq!(s.kind, ScheduleKind::Synthesized);
    assert_eq!(s.placement, Placement::Sequential);
    assert_eq!(s.chunks, 1);
    assert_eq!(s.stage_bounds.as_deref(), Some(&[4u64; 8][..]));
    for stage in 0..8 {
        assert_eq!(s.program(stage).ops.len(), 128, "stage {stage}: op count");
        assert_eq!(s.count(stage, OpKind::Fwd), 64, "stage {stage}: fwds");
        assert_eq!(s.count(stage, OpKind::Bwd), 64, "stage {stage}: bwds");
    }

    let layout = pair_adjacent_layout(8, e.cluster.n_nodes);
    let r = simulate(&e, &s, &layout);
    let cell = "synthesized / pair-adjacent";
    assert_close(r.makespan, 84.54787050101113, "makespan", cell);
    assert_close(r.mfu, 0.1851155939154355, "mfu", cell);
    assert_close(r.bubble_fraction, 0.6669591480213222, "bubble_fraction", cell);
    // pure compute: no evict/load ops, so no transfers and no stalls
    assert_eq!(r.transfer_bytes, 0, "{cell}: transfer_bytes");
    assert_eq!(r.load_stall, 0.0, "{cell}: load_stall");
    assert_eq!(r.oom_stage, None, "{cell}: fits under the tightened HBM");
    assert_eq!(&r.stash_high_water[..], &[4, 4, 4, 3, 3, 3, 2, 1], "{cell}: stash");
    assert_eq!(
        &r.mem_high_water[..],
        &[
            76_572_073_728,
            74_179_747_584,
            74_179_747_584,
            70_703_718_144,
            70_703_718_144,
            70_703_718_144,
            67_227_688_704,
            66_052_152_576,
        ],
        "{cell}: mem_high_water"
    );
    for (stage, &bytes) in r.mem_high_water.iter().enumerate() {
        assert!(bytes <= cap, "stage {stage}: {bytes} B over the {cap} B cap");
    }
}

#[test]
fn repeated_runs_on_one_workspace_are_bit_identical() {
    // all 30 golden cells, twice, through ONE workspace: every buffer
    // reset must be complete or run N+1 leaks state from run N
    let e = paper_experiment(8).unwrap();
    let cells = golden_cells(&e);
    let mut ws = SimWorkspace::new();
    let opts = SimOptions { trace: true, warm: false, recompute: false };
    let first: Vec<_> = cells
        .iter()
        .map(|(_, s, l)| {
            let stats = ws.run(&e, s, l, opts);
            (stats, ws.mem_high_water().to_vec(), ws.stash_high_water().to_vec(), ws.trace().to_vec())
        })
        .collect();
    for (i, (_, s, l)) in cells.iter().enumerate() {
        let stats = ws.run(&e, s, l, opts);
        let (f_stats, f_mem, f_stash, f_trace) = &first[i];
        assert_eq!(&stats, f_stats, "cell {i}: stats drifted on reuse");
        assert_eq!(ws.mem_high_water(), &f_mem[..], "cell {i}");
        assert_eq!(ws.stash_high_water(), &f_stash[..], "cell {i}");
        assert_eq!(ws.trace(), &f_trace[..], "cell {i}");
    }
}
