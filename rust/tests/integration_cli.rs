//! CLI integration: the `bpipe` binary's simulator-path subcommands are
//! the user-facing regeneration interface for every table/figure, so
//! each one must run and emit the expected structure.

use std::process::Command;

fn bpipe(args: &[&str]) -> (bool, String) {
    let exe = env!("CARGO_BIN_EXE_bpipe");
    let out = Command::new(exe).args(args).output().expect("spawn bpipe");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn tables_2_3_5_render() {
    let (ok, t2) = bpipe(&["tables", "--which", "2"]);
    assert!(ok && t2.contains("GPT-3 96B") && t2.contains("9984"));
    let (ok, t3) = bpipe(&["tables", "--which", "3"]);
    assert!(ok && t3.lines().count() == 12 && t3.contains("Unfused"));
    let (ok, t5) = bpipe(&["tables", "--which", "5"]);
    assert!(ok && t5.contains("37.") , "{t5}");
}

#[test]
fn figures_render() {
    let (ok, f1) = bpipe(&["figures", "--which", "1"]);
    assert!(ok && f1.contains("E2") && f1.contains("L2"), "{f1}");
    let (ok, f2) = bpipe(&["figures", "--which", "2"]);
    assert!(ok && f2.contains("100%") && f2.contains("s12"));
}

#[test]
fn simulate_reports_memory_and_mfu() {
    let (ok, out) = bpipe(&["simulate", "--experiment", "8", "--timeline"]);
    assert!(ok, "{out}");
    for needle in ["MFU", "bubble fraction", "stage 0 peak mem", "makespan"] {
        assert!(out.contains(needle), "missing {needle}: {out}");
    }
    // exp 8 without BPipe must flag the OOM
    let (ok, out) = bpipe(&["simulate", "--experiment", "8", "--bpipe", "false"]);
    assert!(ok && out.contains("OOM"), "{out}");
}

#[test]
fn sweep_ranks_one_experiment_grid() {
    // exp (8) × 15 scenarios × 2 layouts through the parallel driver
    let (ok, out) = bpipe(&["sweep", "--experiment", "8"]);
    assert!(ok, "{out}");
    for needle in [
        "1F1B+rebalance", "1F1B+stage-bounds", "interleaved+rebalance", "V-shaped",
        "GPipe", "W-shaped", "pair-adjacent", "sequential", "OOM @ stage", "fits",
        "30 grid cells simulated",
    ] {
        assert!(out.contains(needle), "missing {needle}: {out}");
    }
}

#[test]
fn report_emits_markdown_with_figures() {
    let dir = std::env::temp_dir().join(format!("bpipe-cli-report-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let out_path = dir.join("report.md");
    let (ok, out) = bpipe(&["report", "--experiment", "8", "--out", out_path.to_str().unwrap()]);
    assert!(ok, "{out}");
    assert!(out.contains("5 figures"), "{out}");
    let md = std::fs::read_to_string(&out_path).unwrap();
    assert!(md.matches("<svg").count() >= 3, "≥3 embedded SVG figures");
    for needle in [
        "# BPipe replication report", "Estimator vs DES", "W-shaped", "1F1B+stage-bounds",
    ] {
        assert!(md.contains(needle), "missing {needle}");
    }
}

#[test]
fn sweep_bounds_mode_renders_frontier_and_exports() {
    let dir = std::env::temp_dir().join(format!("bpipe-cli-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let csv = dir.join("bounds.csv");
    let json = dir.join("bounds.json");
    // exp (8) bound-sensitivity grid: 4 families × every bound ≥ 2 × 2
    // layouts, with CSV + JSON export
    let (ok, out) = bpipe(&[
        "sweep", "--experiment", "8", "--bounds",
        "--csv", csv.to_str().unwrap(),
        "--json", json.to_str().unwrap(),
    ]);
    assert!(ok, "{out}");
    for needle in ["bounds", "knee k", "best MFU %", "16..2", "grid cells simulated", "wrote"] {
        assert!(out.contains(needle), "missing {needle}: {out}");
    }
    let csv_text = std::fs::read_to_string(&csv).unwrap();
    assert!(csv_text.starts_with("exp,model,microbatch,scenario,bound,layout,mfu_pct"));
    assert!(csv_text.lines().count() > 100, "exp 8 alone sweeps >100 bound cells");
    let json_text = std::fs::read_to_string(&json).unwrap();
    assert!(json_text.starts_with('[') && json_text.trim_end().ends_with(']'));
    assert!(json_text.contains("\"scenario\":\"GPipe+rebalance\""));
}

#[test]
fn sweep_exports_ranking_grid_csv() {
    let dir = std::env::temp_dir().join(format!("bpipe-cli-rank-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let csv = dir.join("rank.csv");
    let (ok, out) = bpipe(&["sweep", "--experiment", "8", "--csv", csv.to_str().unwrap()]);
    assert!(ok, "{out}");
    let text = std::fs::read_to_string(&csv).unwrap();
    assert_eq!(text.lines().count(), 30 + 1, "header + 30 cells");
    // per-stage cells export their bound vector as ONE quoted field
    assert!(text.contains("\"5,6,6,5,4,3,2,2\""), "{text}");
}

#[test]
fn schedule_subcommand_rebalances_any_kind() {
    let (ok, out) = bpipe(&[
        "schedule", "--p", "8", "--m", "16", "--kind", "interleaved", "--rebalance",
    ]);
    assert!(ok, "{out}");
    assert!(out.contains('E') && out.contains('L'), "{out}");
    let (ok, out) = bpipe(&["schedule", "--p", "4", "--m", "8", "--kind", "vshaped"]);
    assert!(ok && out.lines().count() == 4, "{out}");
}

#[test]
fn estimate_reproduces_worked_example() {
    let (ok, out) = bpipe(&["estimate", "--from", "1:0.378", "--to", "2:0.552"]);
    assert!(ok && out.contains("1.388"), "{out}");
    // LLaMA case → NOT worth it
    let (ok, out) = bpipe(&["estimate", "--from", "2:0.586", "--to", "4:0.619"]);
    assert!(ok && out.contains("NOT worth it"), "{out}");
}

#[test]
fn schedule_subcommand_prints_programs() {
    let (ok, out) = bpipe(&["schedule", "--p", "4", "--m", "8", "--bpipe"]);
    assert!(ok);
    assert_eq!(out.lines().count(), 4);
    assert!(out.contains('E') && out.contains('L'));
    let (ok, out) = bpipe(&["schedule", "--p", "4", "--m", "8", "--kind", "gpipe"]);
    assert!(ok && !out.contains('E'));
}

#[test]
fn train_runs_on_the_sim_backend() {
    // the acceptance-criteria invocation: no artifacts, no pjrt — the
    // synthetic manifest + SimBackend train end to end and exit 0
    let (ok, out) = bpipe(&[
        "train", "--backend", "sim", "--steps", "2", "--microbatches", "4", "--log-every", "1",
    ]);
    assert!(ok, "{out}");
    for needle in ["training:", "first loss", "final loss", "stage 0:", "stash-hw"] {
        assert!(out.contains(needle), "missing {needle}: {out}");
    }

    // a rebalanced zig-zag (v=4) base on 2 physical stages: the REAL
    // pipeline runs the W placement with evictions
    let (ok, out) = bpipe(&[
        "train", "--backend", "sim", "--schedule", "zigzag", "--v", "4", "--p", "2",
        "--steps", "1", "--microbatches", "6", "--rebalance", "--bound", "6",
    ]);
    assert!(ok, "{out}");
    assert!(out.contains("evictions 18"), "W-shaped bound-6 run must evict: {out}");

    // unknown backend fails cleanly
    let (ok, _) = bpipe(&["train", "--backend", "quantum"]);
    assert!(!ok);
}

#[test]
fn check_passes_the_whole_ranking_grid() {
    // the acceptance criterion: all 15 ranking-grid scenarios come out
    // of the analyzer with zero error-level findings
    let (ok, out) = bpipe(&["check", "--grid", "--experiment", "8"]);
    assert!(ok, "{out}");
    assert!(out.contains("15 schedule(s) checked: 0 error(s)"), "{out}");
    for needle in ["1F1B", "W-shaped+stage-bounds", "V-shaped+rebalance"] {
        assert!(out.contains(needle), "missing {needle}: {out}");
    }
    // the capacity pass still warns that un-rebalanced exp-8 baselines
    // would OOM — advisory, not gating
    assert!(out.contains("provably-oom"), "{out}");
}

#[test]
fn check_single_schedule_prints_bounds_and_passes() {
    let (ok, out) = bpipe(&["check", "--schedule", "1f1b", "--p", "4", "--m", "8", "--rebalance"]);
    assert!(ok, "{out}");
    assert!(out.contains("stage |  lo pred  hi | planned"), "{out}");
    assert!(out.contains("ok — no findings"), "{out}");
    assert!(out.contains("1 schedule(s) checked: 0 error(s)"), "{out}");
}

#[test]
fn check_flags_a_broken_schedule_in_human_and_json_form() {
    // undersizing the hot channel deadlocks the V-shaped junction: a
    // named error-level diagnostic and a nonzero exit, in both formats
    let args = ["check", "--schedule", "vshaped", "--p", "2", "--m", "4", "--hot-cap", "1"];
    let (ok, out) = bpipe(&args);
    assert!(!ok, "undersized caps must fail the check: {out}");
    assert!(out.contains("error[deadlock-cycle]"), "{out}");
    assert!(out.contains("act[d1]"), "the cycle must name the junction channel: {out}");

    let (ok, out) = bpipe(&[&args[..], &["--json"]].concat());
    assert!(!ok, "{out}");
    assert!(out.contains("\"code\":\"deadlock-cycle\""), "{out}");
    assert!(out.contains("\"ok\":false"), "{out}");
}

#[test]
fn check_accepts_a_synthesized_schedule() {
    // the CI smoke invocation: synthesize at p=8 m=16 under the default
    // tight cap (90% of exp-8 HBM) and push it through the full static
    // gate — zero error-level findings, exit 0
    let (ok, out) = bpipe(&["check", "--schedule", "synth", "--p", "8", "--m", "16"]);
    assert!(ok, "{out}");
    assert!(out.contains("checking synthesized"), "{out}");
    assert!(out.contains("ok — no findings"), "{out}");
    assert!(out.contains("1 schedule(s) checked: 0 error(s)"), "{out}");
    // the synthesized budgets surface as planned per-stage caps
    assert!(out.contains("stage |  lo pred  hi | planned"), "{out}");

    // an impossible cap is a clean, named failure (not a panic)
    let (ok, out) =
        bpipe(&["check", "--schedule", "synth", "--p", "8", "--m", "16", "--cap-gib", "1"]);
    assert!(!ok, "{out}");
    assert!(out.contains("cannot hold one activation stash"), "{out}");
}

#[test]
fn train_runs_a_synthesized_schedule_on_the_sim_backend() {
    // p must be 8 here: the cost model reshapes experiment 8, and at
    // shallower depths the per-stage weights alone exceed the default
    // tight cap (synthesis correctly refuses)
    let (ok, out) = bpipe(&[
        "train", "--backend", "sim", "--schedule", "synth", "--p", "8",
        "--steps", "1", "--microbatches", "4",
    ]);
    assert!(ok, "{out}");
    for needle in ["synthesized schedule: p=8 m=4", "stash budgets", "first loss", "stage 0:"] {
        assert!(out.contains(needle), "missing {needle}: {out}");
    }
}

#[test]
fn sweep_synth_mode_emits_the_frontier_and_csv() {
    let dir = std::env::temp_dir().join(format!("bpipe-cli-synth-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let csv = dir.join("frontier.csv");
    let (ok, out) =
        bpipe(&["sweep", "--experiment", "8", "--synth", "--csv", csv.to_str().unwrap()]);
    assert!(ok, "{out}");
    assert!(out.contains("found-vs-family frontier"), "{out}");
    assert!(out.contains("synthesized"), "{out}");
    let text = std::fs::read_to_string(&csv).unwrap();
    assert!(text.starts_with("exp,model,microbatch,scenario,bound,layout,mfu_pct"));
    // 15 family cells + the synthesized cell
    assert_eq!(text.lines().count(), 16 + 1, "header + 16 cells: {text}");
    let synth_row = text.lines().find(|l| l.contains("synthesized")).unwrap();
    // under the tight cap every family cell OOMs; the synthesized one fits
    assert!(!synth_row.contains("OOM"), "{synth_row}");
}

#[test]
fn sweep_skip_oom_settles_cells_statically() {
    let (ok, out) = bpipe(&["sweep", "--experiment", "8", "--skip-oom"]);
    assert!(ok, "{out}");
    assert!(out.contains("settled statically"), "{out}");
}

#[test]
fn memory_subcommand_shows_imbalance() {
    let (ok, out) = bpipe(&["memory", "--experiment", "8"]);
    assert!(ok && out.contains("OOM!"), "{out}");
}

#[test]
fn bad_input_fails_cleanly() {
    let (ok, _) = bpipe(&["tables", "--which", "9"]);
    assert!(!ok);
    let (ok, _) = bpipe(&["bogus-subcommand"]);
    assert!(!ok);
    let (ok, _) = bpipe(&["estimate", "--from", "nonsense"]);
    assert!(!ok);
}
