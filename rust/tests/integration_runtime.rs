//! Integration tests over the REAL pipeline path — coordinator + stage
//! workers + activation stores — running in TIER-1 on the in-tree
//! deterministic [`SimBackend`] with a fully in-memory synthetic
//! manifest (no `make artifacts`, no `pjrt` feature).
//!
//! The headline invariants, on real buffers moving through real worker
//! threads:
//!
//! * **BPipe must not change numerics** — the same seed trains to
//!   bit-identical losses with and without eviction, for a 1F1B base
//!   AND a zig-zag (v=4) base, while the evictor stages' stash
//!   high-water drops to the planned bound;
//! * **schedules are execution orders, not programs** — every family
//!   (1F1B, GPipe, interleaved, V, W) over the same virtual depth
//!   computes bit-identical losses;
//! * **checkpoint/resume is exact**, including per-virtual-stage state
//!   of multi-chunk placements.
//!
//! The PJRT twin of this suite (against lowered artifacts) lives in the
//! `pjrt` module at the bottom, gated like the backend itself.

use bpipe::coordinator::{train, RebalancePlan, SyntheticCorpus, TrainConfig};
use bpipe::runtime::{Manifest, SimBackend};
use bpipe::schedule::Family;

/// The synthetic model every test below trains: `stages` VIRTUAL stages
/// (p × chunks), h=16, s=8, b=2, vocab 64.
fn manifest(stages: u64) -> Manifest {
    Manifest::synthetic(stages, 16, 8, 2, 64, &[1, 2])
}

fn cfg(stages: u64) -> TrainConfig {
    TrainConfig {
        manifest: Some(manifest(stages)),
        steps: 2,
        microbatches: 6,
        lr: 2e-3,
        seed: 7,
        ..TrainConfig::default()
    }
}

/// THE BPipe invariant on the real 1F1B pipeline: identical losses,
/// stage-0 stash high-water == the planned bound, eviction counts
/// matching the pairing formula.
#[test]
fn bpipe_run_is_bit_identical_and_balanced() {
    let plain = train::<SimBackend>(&cfg(4)).unwrap();
    let mut c = cfg(4);
    c.rebalance = RebalancePlan::Uniform { bound: None };
    let balanced = train::<SimBackend>(&c).unwrap();

    assert_eq!(plain.losses, balanced.losses, "BPipe changed numerics!");
    assert_eq!(plain.losses.len(), 2);
    assert!(plain.losses.iter().all(|l| l.is_finite() && *l > 0.0));

    let (p, m) = (4u64, c.microbatches);
    let bound = bpipe::model::memory::bpipe_bound(p); // 3
    // stage 0 was the memory hog; now it sits exactly at the bound
    assert_eq!(plain.stage_stats[0].stash_high_water, p.min(m) as usize);
    assert_eq!(balanced.stage_stats[0].stash_high_water, bound as usize);
    // eviction counts follow the closed form, per stage, per step
    for st in &balanced.stage_stats {
        let expect = bpipe::bpipe::pairing::evictions_at(p, st.stage, m) * c.steps;
        assert_eq!(st.evictions, expect, "stage {}", st.stage);
    }
    assert_eq!(balanced.stage_stats[0].evictions, 6, "(m − bound) × steps = 3 × 2");
}

/// The same invariant on a W-shaped (zig-zag v=4) base: rebalancing a
/// multi-chunk placement moves `(mb, chunk)` stashes through the remote
/// stores without touching a single value.
#[test]
fn zigzag_w_bpipe_is_bit_identical_and_bounded() {
    let mut base = cfg(8);
    base.family = Family::ZigZag { v: 4 }; // p = 8 / 4 = 2 physical stages
    let plain = train::<SimBackend>(&base).unwrap();
    assert_eq!(plain.schedule.chunks, 4);
    // natural high-water per stage is [16, 17] at m=6
    assert_eq!(plain.stage_stats[0].stash_high_water, 16);
    assert_eq!(plain.stage_stats[1].stash_high_water, 17);

    let mut reb = base.clone();
    reb.rebalance = RebalancePlan::Uniform { bound: Some(6) };
    let balanced = train::<SimBackend>(&reb).unwrap();
    assert_eq!(plain.losses, balanced.losses, "zig-zag BPipe changed numerics!");
    for st in &balanced.stage_stats {
        assert_eq!(st.stash_high_water, 6, "stage {} must sit at the bound", st.stage);
    }
    // both junction stages shuttle stashes: 18 evictions per step each
    assert_eq!(balanced.stage_stats[0].evictions, 36);
    assert_eq!(balanced.stage_stats[1].evictions, 36);
}

/// Schedules are execution orders of ONE computation: every family over
/// the same virtual depth (8 virtual stages here, hosted on 8, 4 or 2
/// physical workers) trains to bit-identical losses.
#[test]
fn every_family_computes_identical_losses() {
    let families = [
        Family::OneFOneB,          // p = 8
        Family::GPipe,             // p = 8
        Family::Interleaved { v: 2 }, // p = 4
        Family::VShaped,           // p = 4
        Family::ZigZag { v: 4 },   // p = 2
    ];
    let mut reference: Option<Vec<f32>> = None;
    for family in families {
        let mut c = cfg(8);
        c.microbatches = 4; // interleaved needs m % p == 0
        c.family = family;
        let r = train::<SimBackend>(&c).unwrap();
        assert_eq!(r.schedule.chunks, family.chunks(), "{family:?}");
        match &reference {
            None => reference = Some(r.losses),
            Some(want) => assert_eq!(&r.losses, want, "{family:?} diverged"),
        }
    }
}

/// Per-stage (non-uniform, SlimPipe-style) caps on the real pipeline:
/// numerics untouched, every stage within its own bound.
#[test]
fn per_stage_bounds_run_on_the_real_pipeline() {
    let plain = train::<SimBackend>(&cfg(4)).unwrap();
    let bounds = vec![3u64, 2, 2, 2];
    let mut c = cfg(4);
    c.rebalance = RebalancePlan::PerStage { bounds: bounds.clone() };
    let capped = train::<SimBackend>(&c).unwrap();
    assert_eq!(plain.losses, capped.losses);
    for (st, &k) in capped.stage_stats.iter().zip(bounds.iter()) {
        assert!(
            st.stash_high_water as u64 <= k,
            "stage {}: hw {} > bound {k}",
            st.stage,
            st.stash_high_water
        );
    }
    // stage 1 (natural high-water 3 > cap 2) now evicts too
    assert_eq!(capped.stage_stats[1].evictions, 8, "4 evictions × 2 steps");
}

#[test]
fn training_is_deterministic_in_seed() {
    let a = train::<SimBackend>(&cfg(4)).unwrap();
    let b = train::<SimBackend>(&cfg(4)).unwrap();
    assert_eq!(a.losses, b.losses, "same seed must be bit-identical");
    let mut c = cfg(4);
    c.seed = 8;
    let d = train::<SimBackend>(&c).unwrap();
    assert_ne!(a.losses, d.losses, "different seed must differ");
    assert_eq!(a.tokens, 2 * 6 * (2 * 8));
}

/// Checkpoint/resume is exact: interrupt at step 3, resume to step 6,
/// and the resumed losses are bit-identical to an uninterrupted run.
#[test]
fn checkpoint_resume_is_bit_identical() {
    let ckpt = std::env::temp_dir().join(format!("bpipe-sim-resume-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&ckpt);

    let mut base = cfg(4);
    base.steps = 6;
    let uninterrupted = train::<SimBackend>(&base).unwrap();

    let mut first = cfg(4);
    first.steps = 3;
    first.checkpoint_dir = Some(ckpt.clone());
    let run_a = train::<SimBackend>(&first).unwrap();
    assert_eq!(run_a.losses, uninterrupted.losses[..3].to_vec());
    assert!(bpipe::coordinator::CheckpointMeta::exists(&ckpt));

    let mut second = cfg(4);
    second.steps = 6; // TOTAL target; 3 already done
    second.checkpoint_dir = Some(ckpt.clone());
    second.resume = true;
    let run_b = train::<SimBackend>(&second).unwrap();
    assert_eq!(
        run_b.losses,
        uninterrupted.losses[3..].to_vec(),
        "resumed losses must continue the uninterrupted trajectory exactly"
    );

    // mismatched shape is rejected up front
    let mut bad = second.clone();
    bad.microbatches += 1;
    assert!(train::<SimBackend>(&bad).is_err());
    // and so is a different family shape (chunks 2 over 4 virtual stages
    // means p = 2, which contradicts the checkpoint's p = 4)
    let mut wrong_family = second.clone();
    wrong_family.family = Family::VShaped;
    assert!(train::<SimBackend>(&wrong_family).is_err());
    let _ = std::fs::remove_dir_all(&ckpt);
}

/// Multi-chunk checkpointing: a W-shaped run saves one state file per
/// VIRTUAL stage and resumes bit-identically.
#[test]
fn zigzag_checkpoint_resume_is_bit_identical() {
    let ckpt = std::env::temp_dir().join(format!("bpipe-sim-wresume-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&ckpt);
    let mk = || {
        let mut c = cfg(8);
        c.family = Family::ZigZag { v: 4 };
        c.steps = 4;
        c
    };
    let uninterrupted = train::<SimBackend>(&mk()).unwrap();

    let mut first = mk();
    first.steps = 2;
    first.checkpoint_dir = Some(ckpt.clone());
    train::<SimBackend>(&first).unwrap();
    // one state file per virtual stage (p=2 × 4 chunks = 8)
    for virt in 0..8u64 {
        assert!(
            bpipe::coordinator::StageCheckpoint::path(&ckpt, virt).exists(),
            "missing per-virtual-stage checkpoint {virt}"
        );
    }
    let mut second = mk();
    second.checkpoint_dir = Some(ckpt.clone());
    second.resume = true;
    let resumed = train::<SimBackend>(&second).unwrap();
    assert_eq!(resumed.losses, uninterrupted.losses[2..].to_vec());
    let _ = std::fs::remove_dir_all(&ckpt);
}

#[test]
fn synthetic_manifest_round_trips_the_parser() {
    // the in-memory manifest and the on-disk JSON contract stay one
    // format: a synthetic manifest serialized by hand parses back
    let m = manifest(4);
    assert_eq!(m.spec.stages, 4);
    assert_eq!(m.stage_kind(0), "first");
    assert_eq!(m.stage_kind(3), "last");
    assert!(m.param_count("first").unwrap() >= 2);
    assert!(m.meta("mid_fwd_b2").is_ok());
}

#[test]
fn corpus_is_learnable_structure_not_noise() {
    // (backend-independent) — the synthetic corpus has < ln(v) entropy:
    // 75% of transitions are deterministic given the previous token.
    let mut c = SyntheticCorpus::new(4096, 0);
    let (tok, tgt) = c.microbatch(16, 64);
    let rule_hits = tok
        .iter()
        .zip(tgt.iter())
        .filter(|&(&t, &n)| n == (3 * t + 7) % 4096)
        .count() as f64
        / tok.len() as f64;
    assert!(rule_hits > 0.7, "rule fraction {rule_hits}");
}

/// The PJRT twin: the same invariants against lowered XLA artifacts.
/// Needs `make artifacts` + `--features pjrt`; self-skips (loudly) when
/// the artifacts are missing so `cargo test --features pjrt` still works
/// in a fresh checkout.
#[cfg(feature = "pjrt")]
mod pjrt {
    use super::*;
    use bpipe::coordinator::measure_stage;
    use bpipe::runtime::Runtime;
    use std::path::PathBuf;

    fn artifacts() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            Some(dir)
        } else {
            eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
            None
        }
    }

    fn pjrt_cfg(dir: &PathBuf) -> TrainConfig {
        TrainConfig {
            artifacts_dir: dir.clone(),
            steps: 2,
            microbatches: 6,
            lr: 2e-3,
            seed: 7,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn manifest_loads_and_is_consistent() {
        let Some(dir) = artifacts() else { return };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.spec.stages >= 2);
        for kind in ["first", "mid", "last"] {
            assert!(m.param_count(kind).unwrap() > 0);
            for suffix in ["init", "bwd"] {
                assert!(m.path_of(&format!("{kind}_{suffix}")).unwrap().exists());
            }
        }
    }

    #[test]
    fn bpipe_run_is_bit_identical_on_pjrt() {
        let Some(dir) = artifacts() else { return };
        let plain = train::<Runtime>(&pjrt_cfg(&dir)).unwrap();
        let mut c = pjrt_cfg(&dir);
        c.rebalance = RebalancePlan::Uniform { bound: None };
        let balanced = train::<Runtime>(&c).unwrap();
        assert_eq!(plain.losses, balanced.losses, "BPipe changed numerics!");
        assert!(
            balanced.stage_stats[0].stash_high_water < plain.stage_stats[0].stash_high_water
        );
    }

    #[test]
    fn training_reduces_loss_from_ln_v() {
        let Some(dir) = artifacts() else { return };
        let m = Manifest::load(&dir).unwrap();
        let mut c = pjrt_cfg(&dir);
        c.steps = 6;
        let r = train::<Runtime>(&c).unwrap();
        let ln_v = (m.spec.v as f32).ln();
        assert!(
            (r.losses[0] - ln_v).abs() < 0.5,
            "first loss {:.3} should start near ln(v) = {ln_v:.3}",
            r.losses[0]
        );
        assert!(r.final_loss() < r.losses[0] - 0.2, "loss should drop: {:?}", r.losses);
    }

    #[test]
    fn stage_measurement_scales_with_b() {
        let Some(dir) = artifacts() else { return };
        let m = Manifest::load(&dir).unwrap();
        if m.bs_sweep.len() < 2 {
            eprintln!("SKIP: artifact sweep too small");
            return;
        }
        let lo = measure_stage::<Runtime>(&m, m.bs_sweep[0], 2).unwrap();
        let hi = measure_stage::<Runtime>(&m, *m.bs_sweep.last().unwrap(), 2).unwrap();
        assert!(hi.t_b > lo.t_b);
    }
}
