//! Integration tests over the REAL runtime path (PJRT + artifacts).
//!
//! These need `make artifacts` to have run; they self-skip (with a loud
//! message) when the artifacts are missing so `cargo test` still works
//! in a fresh checkout.  CI order: `make artifacts && cargo test`.
//!
//! The headline invariant: **BPipe must not change numerics** — the same
//! seed trains to bit-identical losses with and without eviction, while
//! stage 0's stash high-water drops to the bound.

use std::path::{Path, PathBuf};

use bpipe::coordinator::{measure_stage, train, SyntheticCorpus, TrainConfig};
use bpipe::model::memory::bpipe_bound;
use bpipe::runtime::{literal_f32, Manifest, Runtime};

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    }
}

fn cfg(dir: &Path) -> TrainConfig {
    TrainConfig {
        artifacts_dir: dir.to_path_buf(),
        steps: 2,
        microbatches: 6,
        lr: 2e-3,
        bpipe: false,
        bound: None,
        seed: 7,
        log_every: 0,
        checkpoint_dir: None,
        checkpoint_every: 0,
        resume: false,
    }
}

#[test]
fn manifest_loads_and_is_consistent() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load(&dir).unwrap();
    assert!(m.spec.stages >= 2);
    for kind in ["first", "mid", "last"] {
        assert!(m.param_count(kind).unwrap() > 0);
        for suffix in ["init", "bwd"] {
            assert!(m.path_of(&format!("{kind}_{suffix}")).unwrap().exists());
        }
    }
    // fwd artifact shape matches the spec
    let meta = m.meta("mid_fwd").unwrap();
    assert_eq!(meta.inputs[1].shape, vec![m.spec.b, m.spec.s, m.spec.h]);
}

#[test]
fn executable_round_trip_fwd_shapes() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load(&dir).unwrap();
    let rt = Runtime::cpu().unwrap();
    let fwd = rt.load(&m.path_of("mid_fwd").unwrap()).unwrap();
    let n = m.param_count("mid").unwrap() as usize;
    let spec = &m.spec;
    let act = (spec.b * spec.s * spec.h) as usize;
    let params = xla::Literal::vec1(&vec![0.02f32; n]);
    let x = literal_f32(&vec![0.1f32; act], &[spec.b as i64, spec.s as i64, spec.h as i64]).unwrap();
    let y = fwd.run1(&[&params, &x]).unwrap();
    let out = y.to_vec::<f32>().unwrap();
    assert_eq!(out.len(), act);
    assert!(out.iter().all(|v| v.is_finite()));
}

#[test]
fn init_is_deterministic_in_seed() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load(&dir).unwrap();
    let rt = Runtime::cpu().unwrap();
    let init = rt.load(&m.path_of("mid_init").unwrap()).unwrap();
    let a = init.run1(&[xla::Literal::scalar(3i32)]).unwrap().to_vec::<f32>().unwrap();
    let b = init.run1(&[xla::Literal::scalar(3i32)]).unwrap().to_vec::<f32>().unwrap();
    let c = init.run1(&[xla::Literal::scalar(4i32)]).unwrap().to_vec::<f32>().unwrap();
    assert_eq!(a, b);
    assert_ne!(a, c);
}

/// THE BPipe invariant, on real buffers: identical losses, lower stash
/// high-water, eviction counts matching the pairing formula.
#[test]
fn bpipe_run_is_bit_identical_and_balanced() {
    let Some(dir) = artifacts() else { return };
    let plain = train(&cfg(&dir)).unwrap();
    let mut c = cfg(&dir);
    c.bpipe = true;
    let balanced = train(&c).unwrap();

    assert_eq!(plain.losses, balanced.losses, "BPipe changed numerics!");

    let p = plain.schedule.p;
    let m = c.microbatches;
    let bound = bpipe_bound(p).min(m) as usize;
    // stage 0 was the memory hog; now it obeys the bound
    assert_eq!(plain.stage_stats[0].stash_high_water, (p as usize).min(m as usize));
    assert!(balanced.stage_stats[0].stash_high_water <= bound);
    // eviction counts follow the closed form, per stage, per step
    for st in &balanced.stage_stats {
        let expect = bpipe::bpipe::pairing::evictions_at(p, st.stage, m) * c.steps;
        assert_eq!(st.evictions, expect, "stage {}", st.stage);
    }
}

#[test]
fn training_reduces_loss_from_ln_v() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load(&dir).unwrap();
    let mut c = cfg(&dir);
    c.steps = 6;
    let r = train(&c).unwrap();
    let ln_v = (m.spec.v as f32).ln();
    assert!(
        (r.losses[0] - ln_v).abs() < 0.5,
        "first loss {:.3} should start near ln(v) = {ln_v:.3}",
        r.losses[0]
    );
    assert!(
        r.final_loss() < r.losses[0] - 0.2,
        "loss should drop: {:?}",
        r.losses
    );
    // every loss finite and positive
    assert!(r.losses.iter().all(|l| l.is_finite() && *l > 0.0));
}

#[test]
fn stage_measurement_scales_with_b() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load(&dir).unwrap();
    if m.bs_sweep.len() < 2 {
        eprintln!("SKIP: artifact sweep too small");
        return;
    }
    let b_lo = m.bs_sweep[0];
    let b_hi = *m.bs_sweep.last().unwrap();
    let lo = measure_stage(&dir, b_lo, 2).unwrap();
    let hi = measure_stage(&dir, b_hi, 2).unwrap();
    // bigger microbatch → more time per microbatch, better throughput or
    // at least not catastrophically worse
    assert!(hi.t_b > lo.t_b, "t({b_hi})={:.4}s vs t({b_lo})={:.4}s", hi.t_b, lo.t_b);
    let ratio = hi.flops_per_s / lo.flops_per_s;
    assert!(
        ratio > 0.6,
        "throughput should not collapse with b: ratio {ratio:.3}"
    );
}

/// Checkpoint/resume is exact: interrupt at step 3, resume to step 6,
/// and the resumed losses are bit-identical to an uninterrupted run.
#[test]
fn checkpoint_resume_is_bit_identical() {
    let Some(dir) = artifacts() else { return };
    let ckpt = std::env::temp_dir().join(format!("bpipe-resume-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&ckpt);

    let mut base = cfg(&dir);
    base.steps = 6;
    let uninterrupted = train(&base).unwrap();

    let mut first = cfg(&dir);
    first.steps = 3;
    first.checkpoint_dir = Some(ckpt.clone());
    let run_a = train(&first).unwrap();
    assert_eq!(run_a.losses, uninterrupted.losses[..3].to_vec());
    assert!(bpipe::coordinator::CheckpointMeta::exists(&ckpt));

    let mut second = cfg(&dir);
    second.steps = 6; // TOTAL target; 3 already done
    second.checkpoint_dir = Some(ckpt.clone());
    second.resume = true;
    let run_b = train(&second).unwrap();
    assert_eq!(run_b.losses, uninterrupted.losses[3..].to_vec(),
        "resumed losses must continue the uninterrupted trajectory exactly");

    // mismatched shape is rejected up front
    let mut bad = second.clone();
    bad.microbatches += 1;
    assert!(train(&bad).is_err());
    let _ = std::fs::remove_dir_all(&ckpt);
}

#[test]
fn corpus_is_learnable_structure_not_noise() {
    // (no artifacts needed) — the synthetic corpus has < ln(v) entropy:
    // 75% of transitions are deterministic given the previous token.
    let mut c = SyntheticCorpus::new(4096, 0);
    let (tok, tgt) = c.microbatch(16, 64);
    let rule_hits = tok
        .iter()
        .zip(tgt.iter())
        .filter(|&(&t, &n)| n == (3 * t + 7) % 4096)
        .count() as f64
        / tok.len() as f64;
    assert!(rule_hits > 0.7, "rule fraction {rule_hits}");
}
