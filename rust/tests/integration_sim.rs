//! Integration tests over the simulator path: the paper's quantitative
//! claims must hold end to end (config → schedule → BPipe → DES → MFU).

use bpipe::bpipe::{apply_bpipe, pair_adjacent_layout, sequential_layout};
use bpipe::config::{paper_experiment, paper_experiments, paper_table3_mfu};
use bpipe::estimator::{predicted_speedup, StageMeasurement};
use bpipe::model::memory::MemoryModel;
use bpipe::schedule::one_f_one_b;
use bpipe::sim::{simulate, simulate_experiment, CostModel};

/// Table 3, reproduced: every simulated MFU within a few points of the
/// paper, and — more importantly — every *conclusion* preserved.
#[test]
fn table3_shape_holds() {
    let mfu = |id: u32| simulate_experiment(&paper_experiment(id).unwrap()).mfu_pct();
    // absolute tracking (generous band; our substrate is a simulator)
    for id in 1..=10u32 {
        let ours = mfu(id);
        let paper = paper_table3_mfu(id).unwrap();
        assert!(
            (ours - paper).abs() < 8.0,
            "exp {id}: ours {ours:.1} vs paper {paper:.1}"
        );
    }
    // conclusion 1: BPipe is a big win for GPT-3 with recompute kernels
    let sp_gpt = mfu(8) / mfu(7);
    assert!(sp_gpt > 1.25, "GPT recompute speedup {sp_gpt:.3} (paper 1.35)");
    // conclusion 2: with flash attention the win evaporates (|Δ| small)
    let sp_gpt_flash = mfu(10) / mfu(9);
    assert!(
        (0.93..1.10).contains(&sp_gpt_flash),
        "GPT flash speedup {sp_gpt_flash:.3} (paper 0.994)"
    );
    // conclusion 3: BPipe is NEGATIVE for LLaMA in both kernel regimes
    assert!(mfu(3) < mfu(2), "LLaMA recompute: b=4+BPipe must lose to b=2");
    assert!(mfu(6) < mfu(5), "LLaMA flash: b=4+BPipe must lose to b=2");
}

/// The §4 worked example end to end from OUR numbers: Eq. 4 predicted
/// speedup (from single-stage MFUs) must upper-bound and track the
/// simulated whole-model speedup.
#[test]
fn estimator_tracks_simulator() {
    for (x, y) in [(7u32, 8u32), (9, 10), (5, 6), (2, 3)] {
        let ex = paper_experiment(x).unwrap();
        let ey = paper_experiment(y).unwrap();
        let pred = predicted_speedup(
            128,
            8,
            StageMeasurement { b: ex.parallel.microbatch, mfu_stage: CostModel::new(&ex).single_stage_mfu() },
            StageMeasurement { b: ey.parallel.microbatch, mfu_stage: CostModel::new(&ey).single_stage_mfu() },
        );
        let meas = simulate_experiment(&ey).mfu / simulate_experiment(&ex).mfu;
        // upper bound (the ignored BPipe overhead only hurts), tight-ish
        assert!(
            pred >= meas - 0.01,
            "({x}→{y}): pred {pred:.3} must bound meas {meas:.3}"
        );
        assert!(
            (pred - meas).abs() < 0.10,
            "({x}→{y}): pred {pred:.3} vs meas {meas:.3} — should track within 10%"
        );
    }
}

/// Memory feasibility drives Table 3's structure: the BPipe rows OOM
/// without BPipe, both analytically and in the DES's tracked high-water.
#[test]
fn bpipe_rows_oom_without_bpipe_in_both_models() {
    for id in [3u32, 6, 8, 10] {
        let mut e = paper_experiment(id).unwrap();
        e.bpipe = false;
        let mm = MemoryModel::new(&e);
        assert!(!mm.fits(false), "exp {id} should OOM analytically");
        let r = simulate_experiment(&e);
        assert_eq!(r.oom_stage, Some(0), "exp {id} should OOM at stage 0 in the DES");
        e.bpipe = true;
        let r = simulate_experiment(&e);
        assert!(r.oom_stage.is_none(), "exp {id} must fit with BPipe");
    }
}

/// DES memory accounting brackets the closed-form model for BPipe
/// schedules (evictor capped at the bound, acceptor hosting partner
/// overflow): never below it, and at most ONE transient activation slot
/// above it — the conservative tie-break applies allocations before
/// frees at equal timestamps, so a load starting exactly when a backward
/// retires counts both stashes resident for an instant.
#[test]
fn des_memory_matches_closed_form_with_bpipe() {
    let e = paper_experiment(8).unwrap();
    let r = simulate_experiment(&e);
    let mm = MemoryModel::new(&e);
    let act = mm.activation_bytes_per_microbatch(0);
    for s in 0..e.parallel.p {
        let des = r.mem_high_water[s as usize];
        let cf = mm.peak_bytes_bpipe(s);
        assert!(des >= cf, "stage {s}: DES {des} below closed form {cf}");
        assert!(
            des - cf <= act,
            "stage {s}: DES {des} above closed form {cf} by more than one transient slot"
        );
    }
    // and the transient slot never pushes exp (8) out of memory
    assert!(r.oom_stage.is_none());
}

/// Figure 2's point, quantified: with the pair-adjacent layout the BPipe
/// overhead stays small; the sequential layout pushes transfers onto IB
/// and measurably hurts.
#[test]
fn pair_adjacent_layout_beats_sequential_under_bpipe() {
    let e = paper_experiment(8).unwrap();
    let m = e.parallel.num_microbatches();
    let sched = apply_bpipe(&one_f_one_b(e.parallel.p, m), None);
    let adj = simulate(&e, &sched, &pair_adjacent_layout(e.parallel.p, 4));
    let seq = simulate(&e, &sched, &sequential_layout(e.parallel.p, 4));
    assert!(seq.makespan > adj.makespan, "sequential must be slower");
    assert!(seq.load_stall > adj.load_stall);
    // and the pair-adjacent overhead vs no-BPipe-at-all stays under 5%
    let plain = simulate(&e, &one_f_one_b(e.parallel.p, m), &pair_adjacent_layout(e.parallel.p, 4));
    assert!(adj.makespan / plain.makespan < 1.05);
}

/// Iteration-time sanity at paper scale: GPT-3 96B, B=128 on 32 A100s at
/// ~34-52% MFU means tens of seconds per iteration.
#[test]
fn absolute_iteration_times_are_plausible() {
    for e in paper_experiments() {
        let r = simulate_experiment(&e);
        assert!(
            r.makespan > 10.0 && r.makespan < 120.0,
            "exp {:?}: {:.1}s/iter",
            e.id,
            r.makespan
        );
    }
}

/// The config system round-trips through files and drives the simulator.
#[test]
fn config_file_drives_simulation() {
    let dir = std::env::temp_dir().join(format!("bpipe-it-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp8.cfg");
    let e = paper_experiment(8).unwrap();
    e.save(&path).unwrap();
    let loaded = bpipe::config::ExperimentConfig::load(&path).unwrap();
    assert_eq!(loaded, e);
    let a = simulate_experiment(&e);
    let b = simulate_experiment(&loaded);
    assert_eq!(a.makespan, b.makespan);
}
