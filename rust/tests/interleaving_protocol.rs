//! The dynamic twin of the analyzer's deadlock/progress pass
//! (`analysis::protocol`), in two layers:
//!
//! 1. **Exhaustive model-level interleavings** (`model_*` tests, the set
//!    the advisory `cargo miri` CI leg runs): a memoized DFS enumerates
//!    EVERY reachable state of the [`ProtocolRun`] transition system for
//!    small pipelines (p = 2, m = 2) and checks that every maximal state
//!    — one where no thread can take a step — is the all-finished state
//!    with clean FIFO tags.  This is the direct dynamic justification
//!    for the analyzer's single greedy run: a Kahn network of fixed
//!    per-thread traces over bounded SPSC FIFOs is confluent, so "the
//!    greedy run finishes" must coincide with "every interleaving
//!    finishes", and the DFS verifies exactly that, including on the
//!    undersized-capacity counterexample where NO interleaving finishes.
//!
//! 2. **Real-thread spin-channel semantics**: the coordinator's
//!    [`spin_send`]/[`spin_recv`] primitives are what the model's
//!    Send/Recv transitions abstract.  These tests pin the properties
//!    the abstraction relies on — per-channel FIFO order under
//!    contention on a capacity-1 ring, progress when producer and
//!    consumer spin against each other, and disconnect errors (`Err`)
//!    exactly when the peer endpoint is gone — and then replay whole
//!    [`ProtocolModel`] traces on real OS threads over real
//!    `sync_channel` rings, proving the model-checked schedules also
//!    complete under genuine preemptive scheduling.

use std::collections::HashSet;
use std::sync::mpsc::sync_channel;

use bpipe::analysis::protocol::Dir;
use bpipe::analysis::{ChannelCaps, ProtocolModel, ProtocolRun};
use bpipe::coordinator::{spin_recv, spin_send};
use bpipe::schedule::Family;

/// Exhaustively enumerate every reachable state of the protocol
/// transition system.  Returns `(reachable_states, maximal_states,
/// all_maximal_finished, any_fifo_mismatch)`.
///
/// Memoizing on [`ProtocolRun::state`] (program counters + queue
/// contents) is sound for the properties checked here: whether a thread
/// is enabled and what a `Recv` observes depend only on that state, so
/// two paths reaching the same state have identical futures.
fn explore(model: &ProtocolModel) -> (usize, usize, bool, bool) {
    let mut seen: HashSet<(Vec<usize>, Vec<Vec<u64>>)> = HashSet::new();
    let mut stack = vec![ProtocolRun::new(model)];
    let mut maximal = 0usize;
    let mut all_maximal_finished = true;
    let mut any_fifo = false;
    while let Some(run) = stack.pop() {
        if !seen.insert(run.state()) {
            continue;
        }
        any_fifo |= run
            .diagnostics
            .iter()
            .any(|d| d.code == "fifo-mismatch");
        let mut progressed = false;
        for t in 0..run.num_threads() {
            if run.enabled(t) {
                progressed = true;
                let mut next = run.clone();
                assert!(next.step(t), "enabled thread {t} must be able to step");
                stack.push(next);
            }
        }
        if !progressed {
            maximal += 1;
            all_maximal_finished &= run.all_finished();
        }
    }
    (seen.len(), maximal, all_maximal_finished, any_fifo)
}

/// p = 2, m = 2 instances of every schedule family, all of which the
/// analyzer certifies deadlock-free at run capacities.
fn small_families() -> Vec<(&'static str, ProtocolModel)> {
    [
        Family::OneFOneB,
        Family::GPipe,
        Family::Interleaved { v: 2 },
        Family::VShaped,
    ]
    .into_iter()
    .map(|f| {
        let s = f.build(2, 2);
        let caps = ChannelCaps::for_run(s.m, s.chunks);
        (f.label(), ProtocolModel::build(&s, &caps))
    })
    .collect()
}

/// EVERY interleaving of every small schedule completes: the only
/// maximal state the DFS can reach is the all-finished one, and no
/// interleaving ever observes out-of-FIFO microbatch tags.
#[test]
fn model_every_interleaving_completes_at_run_capacities() {
    for (label, model) in small_families() {
        let (states, maximal, finished, fifo) = explore(&model);
        assert!(
            states > model.threads.len(),
            "{label}: the DFS must branch, saw only {states} states"
        );
        assert!(maximal >= 1, "{label}: at least one maximal state");
        assert!(
            finished,
            "{label}: some interleaving reached a stuck non-final state"
        );
        assert!(!fifo, "{label}: some interleaving saw a FIFO mismatch");
    }
}

/// Confluence, verified dynamically: for each small schedule the greedy
/// run (`ProtocolRun::run`, what the analyzer executes) reaches the same
/// verdict as the exhaustive enumeration.
#[test]
fn model_greedy_verdict_matches_the_exhaustive_one() {
    for (label, model) in small_families() {
        let mut greedy = ProtocolRun::new(&model);
        let diags = greedy.run();
        assert!(
            greedy.all_finished(),
            "{label}: greedy run must finish like every other interleaving"
        );
        assert!(
            !diags.iter().any(|d| d.code == "deadlock-cycle"),
            "{label}: greedy run reported a deadlock the DFS refutes"
        );
    }
}

/// The counterexample direction: with the zig-zag junction's hot
/// channel undersized to capacity 1, *no* interleaving of the V-shaped
/// p = 2 pipeline can finish — the self-channel block is in a single
/// thread's sequential trace, so it is interleaving-independent, which
/// is exactly why the analyzer may condemn it from one greedy run.
#[test]
fn model_undersized_junction_deadlocks_in_every_interleaving() {
    let s = Family::VShaped.build(2, 4);
    let caps = ChannelCaps {
        hot: 1,
        ..ChannelCaps::for_run(s.m, s.chunks)
    };
    let model = ProtocolModel::build(&s, &caps);
    let mut seen: HashSet<(Vec<usize>, Vec<Vec<u64>>)> = HashSet::new();
    let mut stack = vec![ProtocolRun::new(&model)];
    let mut maximal = 0usize;
    while let Some(run) = stack.pop() {
        if !seen.insert(run.state()) {
            continue;
        }
        let mut progressed = false;
        for t in 0..run.num_threads() {
            if run.enabled(t) {
                progressed = true;
                let mut next = run.clone();
                next.step(t);
                stack.push(next);
            }
        }
        if !progressed {
            maximal += 1;
            assert!(
                !run.all_finished(),
                "an interleaving escaped the undersized junction"
            );
        }
    }
    assert!(maximal >= 1);
    // and the analyzer's greedy run names the same defect
    let mut greedy = ProtocolRun::new(&model);
    let diags = greedy.run();
    assert!(
        diags
            .iter()
            .any(|d| d.code == "deadlock-cycle" && d.message.contains("act[d1]")),
        "greedy run must localize the deadlock to the junction channel"
    );
}

// ---------------------------------------------------------------------------
// real-thread spin-channel semantics
// ---------------------------------------------------------------------------

/// FIFO + progress under maximal contention: a capacity-1 ring forces
/// the producer and consumer to alternate, so every element crosses a
/// full/empty boundary and any reordering or lost wakeup would show up
/// as a wrong value or a hang.
#[test]
fn spin_channels_preserve_fifo_on_a_full_ring() {
    const N: u64 = 2_000;
    let (tx, rx) = sync_channel::<(u64, u64)>(1);
    std::thread::scope(|scope| {
        scope.spawn(move || {
            for i in 0..N {
                spin_send(&tx, (i, i * i)).expect("consumer died early");
            }
        });
        for i in 0..N {
            let (k, v) = spin_recv(&rx).expect("producer died early");
            assert_eq!(k, i, "spin channel delivered out of FIFO order");
            assert_eq!(v, i * i);
        }
    });
}

/// Disconnects surface as `Err`, never as a hang: a send into a channel
/// whose receiver is gone fails, and a recv drains buffered messages
/// before failing once the sender is gone.
#[test]
fn spin_channels_error_on_disconnect() {
    let (tx, rx) = sync_channel::<u64>(2);
    drop(rx);
    assert!(spin_send(&tx, 7).is_err(), "send to dropped receiver must fail");

    let (tx, rx) = sync_channel::<u64>(2);
    spin_send(&tx, 1).unwrap();
    spin_send(&tx, 2).unwrap();
    drop(tx);
    assert_eq!(spin_recv(&rx), Ok(1), "buffered messages drain before the error");
    assert_eq!(spin_recv(&rx), Ok(2));
    assert!(spin_recv(&rx).is_err(), "recv from dropped sender must fail");
}

/// Replay a [`ProtocolModel`] on real OS threads: one thread per trace,
/// one `sync_channel` ring per channel spec (same capacities), every op
/// performed with the coordinator's own `spin_send`/`spin_recv`.  The
/// model-level DFS proved these schedules complete under EVERY
/// interleaving; this run checks the abstraction downward — the real
/// primitives under genuine preemptive scheduling also make progress
/// and preserve the per-channel FIFO tags.
fn replay_on_threads(model: &ProtocolModel) {
    // build one ring per channel and hand each endpoint to its one
    // producer / one consumer thread (the model is strictly SPSC)
    let mut senders: Vec<Option<std::sync::mpsc::SyncSender<u64>>> = Vec::new();
    let mut receivers: Vec<Option<std::sync::mpsc::Receiver<u64>>> = Vec::new();
    for spec in &model.channels {
        let (tx, rx) = sync_channel::<u64>(spec.cap);
        senders.push(Some(tx));
        receivers.push(Some(rx));
    }
    std::thread::scope(|scope| {
        for (t, trace) in model.threads.iter().enumerate() {
            let mut txs: Vec<Option<std::sync::mpsc::SyncSender<u64>>> =
                (0..model.channels.len()).map(|_| None).collect();
            let mut rxs: Vec<Option<std::sync::mpsc::Receiver<u64>>> =
                (0..model.channels.len()).map(|_| None).collect();
            for (c, spec) in model.channels.iter().enumerate() {
                if spec.producer == t {
                    txs[c] = senders[c].take();
                }
                if spec.consumer == t {
                    rxs[c] = receivers[c].take();
                }
            }
            scope.spawn(move || {
                for op in &trace.ops {
                    match op.dir {
                        Dir::Send => {
                            let tx = txs[op.chan].as_ref().expect("producer owns its ring");
                            spin_send(tx, op.mb).unwrap_or_else(|_| {
                                panic!("{}: peer died mid-protocol", op.label)
                            });
                        }
                        Dir::Recv => {
                            let rx = rxs[op.chan].as_ref().expect("consumer owns its ring");
                            let got = spin_recv(rx).unwrap_or_else(|_| {
                                panic!("{}: peer died mid-protocol", op.label)
                            });
                            if op.expect {
                                assert_eq!(
                                    got, op.mb,
                                    "{}: FIFO tag mismatch on a real ring",
                                    op.label
                                );
                            }
                        }
                    }
                }
            });
        }
    });
}

#[test]
fn real_threads_complete_every_model_checked_schedule() {
    // several repetitions to vary the OS scheduler's interleaving
    for _ in 0..4 {
        for (_, model) in small_families() {
            replay_on_threads(&model);
        }
    }
}
