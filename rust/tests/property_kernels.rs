//! The vectorized chunk-major kernels and their mirrored-order scalar
//! twins must be **bit-identical** — the 8-lane SIMD rewrite is a speed
//! change, never a numerics change.
//!
//! Three altitudes:
//!
//! * kernel level — every reduction/elementwise kernel vs its
//!   `*_scalar` twin, across ragged lengths (`n % 8 != 0`), signed
//!   zeros and subnormals;
//! * backend level — every sim op through `execute_pooled` under EVERY
//!   donation mask and both argument conventions must reproduce, bit
//!   for bit, a reference computed *entirely from the scalar twins*
//!   (so a chunked/scalar divergence anywhere in the fused paths fails
//!   here even if both paths are internally self-consistent);
//! * pool level — re-executing an op whose outputs were recycled draws
//!   nothing new from the pool (the kernels keep the steady state
//!   allocation-free; `rust/tests/alloc_steady_state.rs` pins the same
//!   invariant for the full training loop).

use bpipe::runtime::{kernels, Arg, Backend, BufferPool, HostTensor, Manifest, SimBackend};

/// `h = 13`, `b·s = 9` positions: the activation length (117) and every
/// parameter row are deliberately NOT multiples of the 8-lane width, so
/// tail handling is exercised in every fused loop.
fn manifest() -> Manifest {
    Manifest::synthetic(4, 13, 3, 3, 32, &[1, 2])
}

/// Deterministic "awkward" f32s: ±0.0, positive and negative
/// subnormals, magnitudes spanning ~30 orders — cancellation-heavy on
/// purpose, so any reassociation between the two loop shapes shows up
/// in the low bits.
fn awkward(n: usize, salt: u64) -> Vec<f32> {
    (0..n)
        .map(|i| match i % 7 {
            0 => 0.0,
            1 => -0.0,
            2 => f32::MIN_POSITIVE / 2.0,
            3 => -f32::MIN_POSITIVE / 4.0,
            4 => kernels::unit(i as u64 ^ salt) * 1e4,
            5 => kernels::unit((i as u64).wrapping_mul(salt | 1)) * 1e-6,
            _ => kernels::unit(i as u64 * 31 + salt),
        })
        .collect()
}

#[test]
fn chunked_kernels_and_their_scalar_twins_are_bit_identical() {
    for n in [0usize, 1, 2, 3, 5, 7, 8, 9, 13, 17, 23, 31, 33, 63, 65, 100, 117, 129, 1000] {
        let x = awkward(n, 1);
        let dy = awkward(n, 9);
        assert_eq!(
            kernels::row_sum(&x).to_bits(),
            kernels::row_sum_scalar(&x).to_bits(),
            "row_sum n={n}"
        );
        let a = kernels::reduce_dot_bias(&dy, &x);
        let s = kernels::reduce_dot_bias_scalar(&dy, &x);
        assert_eq!(a.0.to_bits(), s.0.to_bits(), "dot n={n}");
        assert_eq!(a.1.to_bits(), s.1.to_bits(), "bias n={n}");
    }
    for (positions, h) in [(1usize, 1usize), (2, 3), (3, 13), (5, 8), (7, 11)] {
        let tok: Vec<i32> = (0..positions as i32).map(|i| i * 3 + 1).collect();
        let dy = awkward(positions * h, 5);
        let a = kernels::reduce_emb_bias(&dy, &tok, h);
        let s = kernels::reduce_emb_bias_scalar(&dy, &tok, h);
        assert_eq!(a.0.to_bits(), s.0.to_bits(), "emb {positions}x{h}");
        assert_eq!(a.1.to_bits(), s.1.to_bits(), "emb-bias {positions}x{h}");
        let mut ya = vec![0f32; positions * h];
        let mut yb = ya.clone();
        kernels::fwd_first_fill(&mut ya, &tok, h, 0.75, -0.125);
        kernels::fwd_first_fill_scalar(&mut yb, &tok, h, 0.75, -0.125);
        assert!(
            ya.iter().zip(&yb).all(|(p, q)| p.to_bits() == q.to_bits()),
            "fill {positions}x{h}"
        );
    }
}

#[test]
fn signed_zeros_and_subnormals_survive_both_paths_identically() {
    // one full 8-lane chunk plus a 1-element tail
    let x = vec![
        -0.0f32,
        0.0,
        f32::MIN_POSITIVE / 2.0,
        -f32::MIN_POSITIVE / 2.0,
        -0.0,
        1.0,
        -1.0,
        0.0,
        -0.0,
    ];
    assert_eq!(kernels::row_sum(&x).to_bits(), kernels::row_sum_scalar(&x).to_bits());
    // scaling by a negative flips zero signs — the elementwise twins
    // must agree on the sign bit, not just the value
    let mut a = x.clone();
    let mut b = x.clone();
    kernels::scale_in_place(&mut a, -1.0);
    kernels::scale_in_place_scalar(&mut b, -1.0);
    assert!(a.iter().zip(&b).all(|(p, q)| p.to_bits() == q.to_bits()));
    let mut c = x.clone();
    let mut d = x;
    kernels::affine_in_place(&mut c, -1.0, 0.0);
    kernels::affine_in_place_scalar(&mut d, -1.0, 0.0);
    assert!(c.iter().zip(&d).all(|(p, q)| p.to_bits() == q.to_bits()));
}

#[test]
fn adam_twins_agree_on_awkward_state() {
    let n = 117; // ragged tail
    let (w0, g0, m0) = (awkward(n, 40), awkward(n, 41), awkward(n, 42));
    let v0: Vec<f32> = awkward(n, 43).iter().map(|x| x.abs()).collect();
    let (mut wa, mut ga, mut ma) = (w0.clone(), g0.clone(), m0.clone());
    let (mut wb, mut gb, mut mb) = (w0, g0, m0);
    kernels::adam_update(&mut wa, &mut ga, &mut ma, &v0, 3, 1e-2);
    kernels::adam_update_scalar(&mut wb, &mut gb, &mut mb, &v0, 3, 1e-2);
    for (a, b) in wa.iter().zip(&wb).chain(ga.iter().zip(&gb)).chain(ma.iter().zip(&mb)) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

/// One op case: artifact name, flat inputs (inputs[0] is the
/// params-like leading argument), and the expected outputs computed
/// entirely from the scalar twins.
type Case = (&'static str, Vec<HostTensor>, Vec<HostTensor>);

/// A `[n]` gradient vector with only the two learnable slots set.
fn grad_vec(n: usize, g0: f32, g1: f32) -> HostTensor {
    let mut d = vec![0f32; n];
    d[0] = g0;
    d[1] = g1;
    HostTensor::vec_f32(d)
}

/// Build every sim op's inputs plus its scalar-twin reference outputs.
fn cases(m: &Manifest) -> Vec<Case> {
    let spec = &m.spec;
    let h = spec.h as usize;
    let positions = (spec.b * spec.s) as usize;
    let act = positions * h;
    let act_shape = [spec.b as i64, spec.s as i64, spec.h as i64];
    let tok_shape = [spec.b as i64, spec.s as i64];
    let n_mid = m.param_count("mid").unwrap() as usize;
    let n_first = m.param_count("first").unwrap() as usize;
    let n_last = m.param_count("last").unwrap() as usize;
    assert_ne!(act % kernels::LANES, 0, "the grid must exercise ragged tails");

    let w_of = |n: usize, salt: u64| {
        let mut w = awkward(n, salt);
        (w[0], w[1]) = (0.75, -0.125);
        w
    };
    let tok: Vec<i32> = (0..positions as i32).map(|i| (i * 5 + 1) % spec.v as i32).collect();
    let tok_t = HostTensor::I32 { data: tok.clone(), shape: tok_shape.to_vec() };
    let act_t = |data: Vec<f32>| HostTensor::F32 { data, shape: act_shape.to_vec() };

    let mut cases: Vec<Case> = Vec::new();

    // first_fwd: y[p·h + j] = w0·emb(tok[p], j) + w1
    let w_first = w_of(n_first, 1);
    let mut y_first = vec![0f32; act];
    kernels::fwd_first_fill_scalar(&mut y_first, &tok, h, w_first[0], w_first[1]);
    cases.push((
        "first_fwd",
        vec![HostTensor::vec_f32(w_first.clone()), tok_t.clone()],
        vec![act_t(y_first)],
    ));

    // mid_fwd: y = (1 + w0)·x + w1
    let w_mid = w_of(n_mid, 2);
    let x_mid = awkward(act, 3);
    let mut y_mid = x_mid.clone();
    kernels::affine_in_place_scalar(&mut y_mid, 1.0 + w_mid[0], w_mid[1]);
    cases.push((
        "mid_fwd",
        vec![HostTensor::vec_f32(w_mid.clone()), act_t(x_mid)],
        vec![act_t(y_mid)],
    ));

    // first_bwd: dw = (Σ dy·emb, Σ dy)
    let dy_first = awkward(act, 4);
    let (fg0, fg1) = kernels::reduce_emb_bias_scalar(&dy_first, &tok, h);
    cases.push((
        "first_bwd",
        vec![HostTensor::vec_f32(w_first), tok_t.clone(), act_t(dy_first)],
        vec![grad_vec(n_first, fg0, fg1)],
    ));

    // mid_bwd: dx = dy·(1 + w0), dw = (Σ dy·x, Σ dy)
    let x_bwd = awkward(act, 5);
    let dy_bwd = awkward(act, 6);
    let (mg0, mg1) = kernels::reduce_dot_bias_scalar(&dy_bwd, &x_bwd);
    let mut dx_mid = vec![0f32; act];
    kernels::scale_into_scalar(&mut dx_mid, &dy_bwd, 1.0 + w_mid[0]);
    cases.push((
        "mid_bwd",
        vec![HostTensor::vec_f32(w_mid), act_t(x_bwd), act_t(dy_bwd)],
        vec![act_t(dx_mid), grad_vec(n_mid, mg0, mg1)],
    ));

    // last_bwd: the per-position affine head — row sums through the
    // scalar twin, the cross-position epilogue replicated sequentially
    let w_last = w_of(n_last, 7);
    let x_last = awkward(act, 8);
    let (dx_last, lg0, lg1, loss) = {
        let (w0, w1) = (w_last[0], w_last[1]);
        let mut x = x_last.clone();
        let inv_h = 1.0f32 / h as f32;
        let inv_n = 1.0f32 / tok.len() as f32;
        let inv_v = 1.0f32 / spec.v as f32;
        let (mut loss, mut g0, mut g1) = (0f32, 0f32, 0f32);
        for (p, &t) in tok.iter().enumerate() {
            let mut u = kernels::row_sum_scalar(&x[p * h..(p + 1) * h]);
            u *= inv_h;
            let pred = w0 * u + w1;
            let target = t as f32 * inv_v - 0.5;
            let e = pred - target;
            loss += e * e;
            let dpred = 2.0 * e * inv_n;
            g0 += dpred * u;
            g1 += dpred;
            let dxv = dpred * w0 * inv_h;
            x[p * h..(p + 1) * h].fill(dxv);
        }
        loss *= inv_n;
        (x, g0, g1, loss)
    };
    let mut loss_t = HostTensor::vec_f32(vec![loss]);
    loss_t.set_shape(&[]);
    cases.push((
        "last_bwd",
        vec![HostTensor::vec_f32(w_last), act_t(x_last), tok_t],
        vec![act_t(dx_last), grad_vec(n_last, lg0, lg1), loss_t],
    ));

    // adam: the rotated state triple
    let (w_a, g_a, m_a) = (w_of(n_mid, 20), awkward(n_mid, 21), awkward(n_mid, 22));
    let v_a: Vec<f32> = awkward(n_mid, 23).iter().map(|x| x.abs()).collect();
    let (mut we, mut ge, mut me) = (w_a.clone(), g_a.clone(), m_a.clone());
    kernels::adam_update_scalar(&mut we, &mut ge, &mut me, &v_a, 3, 1e-2);
    cases.push((
        "adam_mid",
        vec![
            HostTensor::vec_f32(w_a),
            HostTensor::vec_f32(g_a),
            HostTensor::vec_f32(m_a),
            HostTensor::vec_f32(v_a),
            HostTensor::scalar_i32(3),
            HostTensor::scalar_f32(1e-2),
        ],
        vec![HostTensor::vec_f32(we), HostTensor::vec_f32(ge), HostTensor::vec_f32(me)],
    ));

    cases
}

/// Bitwise output comparison: shapes must match and every f32 must be
/// identical *as bits* (so a `-0.0` vs `+0.0` divergence fails even
/// though `==` would accept it).
fn assert_bits_eq(got: &[HostTensor], want: &[HostTensor], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: output arity");
    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        assert_eq!(g.shape(), w.shape(), "{ctx}: output {i} shape");
        match (g.f32s(), w.f32s()) {
            (Ok(a), Ok(b)) => {
                assert_eq!(a.len(), b.len(), "{ctx}: output {i} length");
                for (j, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                    assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: output {i}[{j}]: {x} vs {y}");
                }
            }
            _ => {
                assert_eq!(g.i32s().unwrap(), w.i32s().unwrap(), "{ctx}: output {i} (i32)");
            }
        }
    }
}

/// Run one op through `execute_pooled` with the given donation mask
/// (bit i set = input i donated); `params_slot` keeps input 0 as the
/// device-resident leading argument, the worker's convention.
fn run_pooled(
    b: &SimBackend,
    exe: &<SimBackend as Backend>::Exec,
    inputs: &[&HostTensor],
    mask: u32,
    params_slot: bool,
) -> Vec<HostTensor> {
    let mut pool = BufferPool::new();
    let mut out = Vec::new();
    let skip = usize::from(params_slot);
    let mut args: Vec<Arg<'_>> = inputs[skip..]
        .iter()
        .enumerate()
        .map(|(i, &t)| {
            if mask >> (i + skip) & 1 == 1 {
                Arg::Donated(t.clone())
            } else {
                Arg::Borrowed(t)
            }
        })
        .collect();
    let params = if params_slot { Some(inputs[0]) } else { None };
    b.execute_pooled(exe, params, &mut args, &mut pool, &mut out)
        .expect("pooled execution failed");
    out
}

#[test]
fn every_op_matches_its_scalar_reference_under_every_donation_mask() {
    let m = manifest();
    let b = SimBackend::create(&m).unwrap();
    for (name, inputs, expected) in cases(&m) {
        let exe = b.compile(&m, name).unwrap();
        let refs: Vec<&HostTensor> = inputs.iter().collect();
        let fresh = b.execute(&exe, &refs).unwrap();
        assert_bits_eq(&fresh, &expected, &format!("{name} (owned)"));
        let k = inputs.len() as u32;
        for mask in 0..(1u32 << k) {
            for params_slot in [false, true] {
                if params_slot && mask & 1 == 1 {
                    continue; // the params slot is borrowed by definition
                }
                let out = run_pooled(&b, &exe, &refs, mask, params_slot);
                assert_bits_eq(
                    &out,
                    &expected,
                    &format!("{name} mask {mask:#b} params_slot={params_slot}"),
                );
            }
        }
    }
}

#[test]
fn steady_state_reexecution_draws_nothing_new_from_the_pool() {
    let m = manifest();
    let b = SimBackend::create(&m).unwrap();
    for (name, inputs, _) in cases(&m) {
        let exe = b.compile(&m, name).unwrap();
        let mut pool = BufferPool::new();
        let mut out = Vec::new();
        let run = |pool: &mut BufferPool, out: &mut Vec<HostTensor>| {
            let mut args: Vec<Arg<'_>> = inputs[1..].iter().map(Arg::Borrowed).collect();
            b.execute_pooled(&exe, Some(&inputs[0]), &mut args, pool, out).unwrap();
        };
        run(&mut pool, &mut out);
        let after_first = pool.misses;
        for round in 0..3 {
            for t in out.drain(..) {
                pool.give(t);
            }
            run(&mut pool, &mut out);
            assert_eq!(
                pool.misses, after_first,
                "{name}: steady-state re-execution allocated (round {round})"
            );
        }
    }
}
