//! Pooled/donating execution must be **bit-identical** to
//! fresh-allocation execution — the buffer-lifecycle layer is a memory
//! optimization, never a numerics change.
//!
//! Two altitudes:
//!
//! * op level — every sim artifact op, executed through
//!   `execute_pooled` under EVERY donation mask (each subset of inputs
//!   donated) and both argument conventions (params device-resident vs
//!   inline), must reproduce `execute`'s outputs exactly (data AND
//!   shape);
//! * pipeline level — every schedule family × {rebalance off, uniform
//!   bound, per-stage bounds}, trained end to end on the donating
//!   [`SimBackend`] and on [`UnpooledSimBackend`] (the trait's
//!   fresh-allocation defaults), must produce identical losses and
//!   identical stash/eviction behavior.

use bpipe::coordinator::{plan_schedule, train, RebalancePlan, TrainConfig};
use bpipe::runtime::{
    Arg, Backend, BufferPool, HostTensor, Manifest, SimBackend, UnpooledSimBackend,
};
use bpipe::schedule::Family;

fn manifest() -> Manifest {
    Manifest::synthetic(4, 8, 4, 2, 32, &[1, 2])
}

/// Deterministic pseudo-random f32 tensor.
fn f32_t(len: usize, shape: &[i64], salt: u64) -> HostTensor {
    let data: Vec<f32> = (0..len)
        .map(|i| {
            let z = (i as u64).wrapping_mul(0x9E37_79B9).wrapping_add(salt.wrapping_mul(31));
            ((z % 2003) as f32) * 1e-3 - 1.0
        })
        .collect();
    HostTensor::F32 { data, shape: shape.to_vec() }
}

fn i32_t(len: usize, shape: &[i64], modulo: i32) -> HostTensor {
    let data: Vec<i32> = (0..len as i32).map(|i| (i * 7 + 3) % modulo).collect();
    HostTensor::I32 { data, shape: shape.to_vec() }
}

/// Non-negative variant (Adam's second moment must stay ≥ 0 or the
/// update is NaN in both paths, which `assert_eq!` cannot compare).
fn f32_nonneg(len: usize, shape: &[i64], salt: u64) -> HostTensor {
    let mut t = f32_t(len, shape, salt);
    for v in t.f32s_mut().unwrap() {
        *v = v.abs();
    }
    t
}

/// Run one op through `execute_pooled` with the given donation mask
/// (bit i set = input i donated).  `params_slot` keeps input 0 as the
/// device-resident leading argument, the worker's convention.
fn run_pooled(
    b: &SimBackend,
    exe: &<SimBackend as Backend>::Exec,
    inputs: &[&HostTensor],
    mask: u32,
    params_slot: bool,
) -> Vec<HostTensor> {
    let mut pool = BufferPool::new();
    let mut out = Vec::new();
    let skip = usize::from(params_slot);
    let mut args: Vec<Arg<'_>> = inputs[skip..]
        .iter()
        .enumerate()
        .map(|(i, &t)| {
            if mask >> (i + skip) & 1 == 1 {
                Arg::Donated(t.clone())
            } else {
                Arg::Borrowed(t)
            }
        })
        .collect();
    let params = if params_slot { Some(inputs[0]) } else { None };
    b.execute_pooled(exe, params, &mut args, &mut pool, &mut out)
        .expect("pooled execution failed");
    out
}

#[test]
fn every_op_is_mask_invariant() {
    let m = manifest();
    let b = SimBackend::create(&m).unwrap();
    let spec = &m.spec;
    let h = spec.h as usize;
    let positions = (spec.b * spec.s) as usize;
    let act = positions * h;
    let act_shape = [spec.b as i64, spec.s as i64, spec.h as i64];
    let tok_shape = [spec.b as i64, spec.s as i64];

    let n_mid = m.param_count("mid").unwrap() as usize;
    let n_first = m.param_count("first").unwrap() as usize;
    let n_last = m.param_count("last").unwrap() as usize;

    // (artifact, inputs) per op — inputs[0] is always the params-like arg
    let cases: Vec<(&str, Vec<HostTensor>)> = vec![
        ("mid_init", vec![HostTensor::scalar_i32(11)]),
        (
            "first_fwd",
            vec![f32_t(n_first, &[n_first as i64], 1), i32_t(positions, &tok_shape, spec.v as i32)],
        ),
        ("mid_fwd", vec![f32_t(n_mid, &[n_mid as i64], 2), f32_t(act, &act_shape, 3)]),
        (
            "first_bwd",
            vec![
                f32_t(n_first, &[n_first as i64], 4),
                i32_t(positions, &tok_shape, spec.v as i32),
                f32_t(act, &act_shape, 5),
            ],
        ),
        (
            "mid_bwd",
            vec![
                f32_t(n_mid, &[n_mid as i64], 6),
                f32_t(act, &act_shape, 7),
                f32_t(act, &act_shape, 8),
            ],
        ),
        (
            "last_bwd",
            vec![
                f32_t(n_last, &[n_last as i64], 9),
                f32_t(act, &act_shape, 10),
                i32_t(positions, &tok_shape, spec.v as i32),
            ],
        ),
        (
            "adam_mid",
            vec![
                f32_t(n_mid, &[n_mid as i64], 12),
                f32_t(n_mid, &[n_mid as i64], 13),
                f32_t(n_mid, &[n_mid as i64], 14),
                f32_nonneg(n_mid, &[n_mid as i64], 15),
                HostTensor::scalar_i32(3),
                HostTensor::scalar_f32(1e-2),
            ],
        ),
    ];

    for (name, inputs) in &cases {
        let exe = b.compile(&m, name).unwrap();
        let refs: Vec<&HostTensor> = inputs.iter().collect();
        let fresh = b.execute(&exe, &refs).unwrap();
        let k = inputs.len() as u32;
        for mask in 0..(1u32 << k) {
            for params_slot in [false, true] {
                if params_slot && mask & 1 == 1 {
                    continue; // the params slot is borrowed by definition
                }
                let pooled = run_pooled(&b, &exe, &refs, mask, params_slot);
                assert_eq!(
                    pooled, fresh,
                    "{name}: mask {mask:#b} (params_slot={params_slot}) diverged"
                );
            }
        }
    }
}

#[test]
fn default_trait_path_matches_the_donating_override() {
    // UnpooledSimBackend has NO execute_pooled override, so this pins the
    // trait's default (upload + execute + recycle) against the sim's
    // in-place implementation
    let m = manifest();
    let b = SimBackend::create(&m).unwrap();
    let ub = UnpooledSimBackend::create(&m).unwrap();
    let n = m.param_count("mid").unwrap() as usize;
    let w = f32_t(n, &[n as i64], 21);
    let x = f32_t(16, &[16], 22);
    let dy = f32_t(16, &[16], 23);
    for name in ["mid_fwd", "mid_bwd"] {
        let exe_a = b.compile(&m, name).unwrap();
        let exe_b = ub.compile(&m, name).unwrap();
        let inputs: Vec<&HostTensor> =
            if name == "mid_fwd" { vec![&w, &x] } else { vec![&w, &x, &dy] };
        let run = |donate_all: bool| -> (Vec<HostTensor>, Vec<HostTensor>) {
            let mask = if donate_all { u32::MAX ^ 1 } else { 0 };
            let mut pool = BufferPool::new();
            let mut out_b = Vec::new();
            let mut args: Vec<Arg<'_>> = inputs[1..]
                .iter()
                .map(|&t| {
                    if donate_all { Arg::Donated(t.clone()) } else { Arg::Borrowed(t) }
                })
                .collect();
            ub.execute_pooled(&exe_b, Some(inputs[0]), &mut args, &mut pool, &mut out_b)
                .unwrap();
            let pooled = run_pooled(&b, &exe_a, &inputs, mask, true);
            (pooled, out_b)
        };
        for donate_all in [false, true] {
            let (pooled, unpooled) = run(donate_all);
            assert_eq!(pooled, unpooled, "{name} (donate_all={donate_all}) diverged");
        }
    }
}

/// End to end: the donating pipeline vs the owned-value pipeline, for
/// all five schedule families × three rebalance plans over one virtual
/// depth — losses, stash high-waters and eviction counts all identical.
#[test]
fn pooled_training_matches_owned_baseline_across_families_and_plans() {
    let families = [
        Family::OneFOneB,
        Family::GPipe,
        Family::Interleaved { v: 2 },
        Family::VShaped,
        Family::ZigZag { v: 4 },
    ];
    let m = 4u64;
    for family in families {
        let p = 8 / family.chunks();
        let uniform_caps: Vec<u64> = {
            let (_s, caps) = plan_schedule(family, p, m, &RebalancePlan::Uniform { bound: None });
            caps.iter().map(|&c| c as u64).collect()
        };
        let plans = [
            RebalancePlan::Off,
            RebalancePlan::Uniform { bound: None },
            RebalancePlan::PerStage { bounds: uniform_caps },
        ];
        for plan in plans {
            let cfg = TrainConfig {
                manifest: Some(Manifest::synthetic(8, 16, 8, 2, 64, &[1, 2])),
                family,
                steps: 2,
                microbatches: m,
                lr: 2e-3,
                seed: 7,
                rebalance: plan.clone(),
                ..TrainConfig::default()
            };
            let pooled = train::<SimBackend>(&cfg).unwrap();
            let owned = train::<UnpooledSimBackend>(&cfg).unwrap();
            assert_eq!(
                pooled.losses, owned.losses,
                "{family:?} × {plan:?}: pooled and owned losses diverged"
            );
            for (a, b) in pooled.stage_stats.iter().zip(owned.stage_stats.iter()) {
                assert_eq!(a.stash_high_water, b.stash_high_water, "{family:?} × {plan:?}");
                assert_eq!(a.evictions, b.evictions, "{family:?} × {plan:?}");
            }
        }
    }
}
