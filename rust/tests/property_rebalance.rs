//! Grid + property tests for the generalized rebalancing transform: for
//! every (p, m, v, bound) cell the rebalanced schedule must validate,
//! hold the bound at EVERY op boundary on EVERY stage, and never run a
//! backward while its stash is evicted — on interleaved and V-shaped
//! bases, not just 1F1B.

use bpipe::bpipe::{derived_bound, pair_adjacent_layout, rebalance};
use bpipe::config::paper_experiment;
use bpipe::model::memory::bpipe_bound;
use bpipe::schedule::{interleaved, one_f_one_b, v_shaped, validate, OpKind, Schedule};
use bpipe::sim::simulate;

/// Running stash count ≤ bound after every single op (stronger phrasing
/// of `stash_high_water() ≤ bound`: checked boundary by boundary).
fn assert_bounded_at_every_boundary(s: &Schedule, bound: i64) {
    for prog in &s.programs {
        let mut cur = 0i64;
        for (at, op) in prog.ops.iter().enumerate() {
            match op.kind {
                OpKind::Fwd | OpKind::Load => cur += 1,
                OpKind::Bwd | OpKind::Evict => cur -= 1,
            }
            assert!(
                cur <= bound,
                "stage {} op {at} ({op:?}): resident {cur} > bound {bound}",
                prog.stage
            );
            assert!(cur >= 0, "stage {} op {at}: negative residency", prog.stage);
        }
    }
}

/// No backward may run while its (mb, chunk) stash is off-device.
fn assert_load_precedes_bwd(s: &Schedule) {
    for prog in &s.programs {
        let mut evicted = std::collections::HashSet::new();
        for op in &prog.ops {
            let key = (op.mb, op.chunk);
            match op.kind {
                OpKind::Evict => {
                    evicted.insert(key);
                }
                OpKind::Load => {
                    evicted.remove(&key);
                }
                OpKind::Bwd => {
                    assert!(
                        !evicted.contains(&key),
                        "stage {}: bwd {key:?} while evicted",
                        prog.stage
                    );
                }
                OpKind::Fwd => {}
            }
        }
    }
}

#[test]
fn grid_interleaved_bases_hold_any_bound() {
    for p in [2u64, 4, 8] {
        for mult in [1u64, 2, 4] {
            let m = p * mult;
            for v in [1u64, 2, 4] {
                let base = interleaved(p, m, v);
                let natural: i64 =
                    (0..p).map(|s| base.program(s).stash_high_water()).max().unwrap();
                let candidates = [
                    Some(bpipe_bound(p)),
                    Some(2),
                    Some(3),
                    Some((natural - 1).max(2) as u64),
                    Some((natural + 1) as u64),
                    None, // derived pair-mean default
                ];
                for bound in candidates {
                    let rb = rebalance(&base, bound);
                    validate(&rb).unwrap_or_else(|e| {
                        panic!("p={p} m={m} v={v} bound={bound:?}: {e}")
                    });
                    let k = bound.unwrap_or_else(|| derived_bound(&base)) as i64;
                    assert_bounded_at_every_boundary(&rb, k);
                    assert_load_precedes_bwd(&rb);
                }
            }
        }
    }
}

#[test]
fn grid_v_shaped_bases_hold_any_bound() {
    for p in [2u64, 4, 8] {
        for mult in [1u64, 2, 4] {
            let m = p * mult;
            let base = v_shaped(p, m);
            for bound in [Some(3u64), Some(bpipe_bound(p)), None] {
                let rb = rebalance(&base, bound);
                validate(&rb)
                    .unwrap_or_else(|e| panic!("p={p} m={m} bound={bound:?}: {e}"));
                let k = bound.unwrap_or_else(|| derived_bound(&base)) as i64;
                assert_bounded_at_every_boundary(&rb, k);
                assert_load_precedes_bwd(&rb);
            }
        }
    }
}

#[test]
fn grid_1f1b_bases_match_paper_bound_semantics() {
    for p in [2u64, 4, 8, 16] {
        for m in [1u64, p, 4 * p, 100] {
            let base = one_f_one_b(p, m);
            let rb = rebalance(&base, None);
            validate(&rb).unwrap();
            // derived default == paper bound for even p (unit-tested in
            // bpipe::rebalance); the schedule must hold it everywhere
            assert_bounded_at_every_boundary(&rb, derived_bound(&base) as i64);
        }
    }
}

/// The ISSUE's acceptance scenario, end to end: rebalance(interleaved(8,
/// 32, 2), bound) validates and simulates with every stage's own
/// residency ≤ bound at every boundary, and loads always precede bwds.
#[test]
fn acceptance_rebalanced_interleaved_8_32_2_end_to_end() {
    let mut e = paper_experiment(8).unwrap();
    e.parallel.global_batch = 32 * e.parallel.microbatch; // m = 32
    let base = interleaved(8, 32, 2);
    let layout = pair_adjacent_layout(8, e.cluster.n_nodes);
    for bound in [Some(4u64), Some(8), None] {
        let rb = rebalance(&base, bound);
        validate(&rb).unwrap();
        let k = bound.unwrap_or_else(|| derived_bound(&base)) as i64;
        assert_bounded_at_every_boundary(&rb, k);
        assert_load_precedes_bwd(&rb);
        let r = simulate(&e, &rb, &layout);
        assert!(r.makespan > 0.0 && r.mfu > 0.0 && r.mfu < 1.0);
        // the DAG executed completely (simulate would panic on a cycle);
        // the trace holds one timed event per scheduled op
        assert_eq!(r.trace.len(), rb.num_ops());
    }
}

/// Rebalancing an interleaved schedule with the derived bound must
/// strictly flatten the per-stage residency ramp.
#[test]
fn derived_bound_flattens_interleaved_ramp() {
    let base = interleaved(8, 64, 2);
    let rb = rebalance(&base, None);
    let hw = |s: &Schedule| -> Vec<i64> {
        (0..8).map(|st| s.program(st).stash_high_water()).collect()
    };
    let spread = |v: &[i64]| v.iter().max().unwrap() - v.iter().min().unwrap();
    assert!(
        spread(&hw(&rb)) < spread(&hw(&base)),
        "{:?} vs {:?}",
        hw(&rb),
        hw(&base)
    );
}
