//! Property-based tests over schedule generators and the BPipe transform.
//!
//! A hand-rolled property driver (the build is offline; no proptest):
//! [`bpipe::util::SplitMix64`] generates hundreds of random (p, m, bound)
//! cases per property; every case is checked against the full invariant
//! set.  Failures print the seed + case for replay.

use bpipe::bpipe::{
    apply_bpipe, capacity_stage_bounds, pair_adjacent_layout, pairing, rebalance_bounded,
    sequential_layout,
};
use bpipe::model::memory::{bpipe_bound, one_f_one_b_in_flight};
use bpipe::schedule::{gpipe, interleaved, one_f_one_b, validate, zigzag, OpKind};
use bpipe::util::SplitMix64;

const CASES: u64 = 300;

/// Random (p, m) with p ∈ [1, 24], m ∈ [1, 160].
fn random_pm(rng: &mut SplitMix64) -> (u64, u64) {
    (rng.range(1, 24), rng.range(1, 160))
}

#[test]
fn prop_1f1b_always_validates_with_exact_high_water() {
    let mut rng = SplitMix64::new(0xF1F1B);
    for case in 0..CASES {
        let (p, m) = random_pm(&mut rng);
        let s = one_f_one_b(p, m);
        validate(&s).unwrap_or_else(|e| panic!("case {case} (p={p}, m={m}): {e}"));
        for st in 0..p {
            assert_eq!(
                s.program(st).stash_high_water(),
                one_f_one_b_in_flight(p, st, m) as i64,
                "case {case} (p={p}, m={m}) stage {st}"
            );
        }
    }
}

#[test]
fn prop_gpipe_always_validates_with_m_high_water() {
    let mut rng = SplitMix64::new(0x6717E);
    for case in 0..CASES {
        let (p, m) = random_pm(&mut rng);
        let s = gpipe(p, m);
        validate(&s).unwrap_or_else(|e| panic!("case {case} (p={p}, m={m}): {e}"));
        for st in 0..p {
            assert_eq!(s.program(st).stash_high_water(), m as i64);
        }
    }
}

#[test]
fn prop_interleaved_validates_for_divisible_m() {
    let mut rng = SplitMix64::new(0x1417);
    for case in 0..CASES {
        let p = rng.range(1, 12);
        let m = p * rng.range(1, 12);
        let v = rng.range(1, 4);
        let s = interleaved(p, m, v);
        validate(&s)
            .unwrap_or_else(|e| panic!("case {case} (p={p}, m={m}, v={v}): {e}"));
        // op-count identity: m·v forwards and backwards per stage
        for st in 0..p {
            assert_eq!(s.count(st, OpKind::Fwd) as u64, m * v);
            assert_eq!(s.count(st, OpKind::Bwd) as u64, m * v);
        }
    }
}

#[test]
fn prop_bpipe_bounds_and_validates() {
    let mut rng = SplitMix64::new(0xB19E);
    for case in 0..CASES {
        let p = rng.range(2, 24);
        let m = rng.range(1, 160);
        // default bound, plus random tighter bounds ≥ 2
        let bound = if rng.next_f64() < 0.5 {
            None
        } else {
            Some(rng.range(2, bpipe_bound(p).max(2)))
        };
        let s = apply_bpipe(&one_f_one_b(p, m), bound);
        validate(&s).unwrap_or_else(|e| panic!("case {case} (p={p}, m={m}, bound={bound:?}): {e}"));
        let k = bound.unwrap_or_else(|| bpipe_bound(p)) as i64;
        for st in 0..p {
            assert!(
                s.program(st).stash_high_water() <= k,
                "case {case} (p={p}, m={m}, bound={bound:?}) stage {st}: hw {} > {k}",
                s.program(st).stash_high_water()
            );
        }
    }
}

#[test]
fn prop_bpipe_preserves_compute_ops_exactly() {
    // BPipe only ADDS Evict/Load; the Fwd/Bwd subsequence is untouched.
    let mut rng = SplitMix64::new(0xC0DE);
    for case in 0..CASES {
        let p = rng.range(2, 16);
        let m = rng.range(1, 96);
        let base = one_f_one_b(p, m);
        let bp = apply_bpipe(&base, None);
        for st in 0..p {
            let compute = |prog: &bpipe::schedule::StageProgram| {
                prog.ops
                    .iter()
                    .filter(|o| matches!(o.kind, OpKind::Fwd | OpKind::Bwd))
                    .cloned()
                    .collect::<Vec<_>>()
            };
            assert_eq!(
                compute(base.program(st)),
                compute(bp.program(st)),
                "case {case} (p={p}, m={m}) stage {st}"
            );
        }
    }
}

#[test]
fn prop_bpipe_evict_load_symmetry_and_counts() {
    let mut rng = SplitMix64::new(0x5EED);
    for case in 0..CASES {
        let p = rng.range(2, 20);
        let m = rng.range(1, 120);
        let bp = apply_bpipe(&one_f_one_b(p, m), None);
        for st in 0..p {
            let evicts = bp.count(st, OpKind::Evict) as u64;
            let loads = bp.count(st, OpKind::Load) as u64;
            assert_eq!(evicts, loads, "case {case} (p={p}, m={m}) stage {st}");
            assert_eq!(
                evicts,
                pairing::evictions_at(p, st, m),
                "case {case} (p={p}, m={m}) stage {st}"
            );
        }
    }
}

#[test]
fn prop_zigzag_validates_with_exact_op_counts() {
    // the W/zig-zag generators must uphold every per-stage invariant for
    // arbitrary (p, m, v), and run v·m forwards + backwards per stage
    let mut rng = SplitMix64::new(0x2162A6);
    for case in 0..CASES {
        let p = rng.range(1, 12);
        let m = rng.range(1, 48);
        let v = rng.range(1, 6);
        let s = zigzag(p, m, v);
        validate(&s).unwrap_or_else(|e| panic!("case {case} (p={p}, m={m}, v={v}): {e}"));
        for st in 0..p {
            assert_eq!(s.count(st, OpKind::Fwd) as u64, v * m, "case {case} stage {st}");
            assert_eq!(s.count(st, OpKind::Bwd) as u64, v * m, "case {case} stage {st}");
        }
    }
}

#[test]
fn prop_even_zigzag_balanced_by_placement() {
    // the placement-balance property the W inherits from the V: for even
    // v, every down-sweep pairs with an up-sweep, so the per-stage stash
    // high-water spread stays ≤ 1 wherever microbatches saturate the
    // virtual pipeline (m ≥ v·p, the regime the paper's experiments run)
    let mut rng = SplitMix64::new(0xBA1A2CE);
    for case in 0..CASES {
        let p = rng.range(2, 10);
        let v = 2 * rng.range(1, 2); // 2 or 4 (the V and the W)
        let m = v * p + rng.range(0, 32);
        let s = zigzag(p, m, v);
        let hws: Vec<i64> = (0..p).map(|st| s.program(st).stash_high_water()).collect();
        let spread = hws.iter().max().unwrap() - hws.iter().min().unwrap();
        assert!(
            spread <= 1,
            "case {case} (p={p}, m={m}, v={v}): spread {spread} from {hws:?}"
        );
    }
}

#[test]
fn prop_zigzag_rebalances_at_any_feasible_bound() {
    // rebalance composes with zig-zag bases across random tighter bounds
    let mut rng = SplitMix64::new(0x2162B0);
    for case in 0..CASES / 3 {
        let p = rng.range(2, 8);
        let m = rng.range(1, 24);
        let v = rng.range(2, 5);
        let base = zigzag(p, m, v);
        let derived = bpipe::bpipe::derived_bound(&base);
        let k = rng.range(2, derived.max(2));
        let rb = bpipe::bpipe::rebalance(&base, Some(k));
        validate(&rb)
            .unwrap_or_else(|e| panic!("case {case} (p={p}, m={m}, v={v}, k={k}): {e}"));
        for st in 0..p {
            assert!(rb.program(st).stash_high_water() <= k as i64, "case {case} stage {st}");
        }
    }
}

#[test]
fn prop_capacity_bounds_always_admit_a_valid_rebalance() {
    // per-stage capacity bounds are derived from the memory model for
    // arbitrary bases; the bounded transform must validate for all of
    // them, and every bound must sit in [2, natural high-water ∨ 2]
    let mut rng = SplitMix64::new(0x51B0);
    let e = bpipe::config::paper_experiment(8).unwrap();
    for case in 0..CASES / 6 {
        let p = e.parallel.p;
        let m = p * rng.range(1, 9);
        let base = match rng.range(0, 4) {
            0 => one_f_one_b(p, m),
            1 => gpipe(p, m),
            2 => interleaved(p, m, rng.range(1, 4)),
            _ => zigzag(p, m, rng.range(1, 5)),
        };
        let bounds = capacity_stage_bounds(&e, &base);
        for (st, &k) in bounds.iter().enumerate() {
            let hw = base.program(st as u64).stash_high_water().max(2);
            assert!(
                (2..=hw as u64).contains(&k),
                "case {case} {:?} stage {st}: bound {k} outside [2, {hw}]",
                base.kind
            );
        }
        let rb = rebalance_bounded(&base, &bounds);
        validate(&rb).unwrap_or_else(|err| panic!("case {case} {:?}: {err}", base.kind));
    }
}

#[test]
fn prop_pairing_involution_and_acceptor_bound() {
    let mut rng = SplitMix64::new(0xAB1E);
    for _ in 0..CASES {
        let p = rng.range(2, 64);
        let m = rng.range(1, 256);
        for x in 0..p {
            assert_eq!(pairing::partner(p, pairing::partner(p, x)), x);
            // a stage never both evicts and accepts
            assert!(!(pairing::is_evictor(p, x, m) && pairing::is_acceptor(p, x, m)));
            // acceptor's total stays within the bound
            let own = one_f_one_b_in_flight(p, x, m);
            if own <= bpipe_bound(p) {
                assert!(own + pairing::acceptor_extra_stashes(p, x, m) <= bpipe_bound(p));
            }
        }
    }
}

#[test]
fn prop_pair_adjacent_layout_always_intra_node() {
    let mut rng = SplitMix64::new(0x1A40);
    for _ in 0..CASES {
        let n_nodes = rng.range(1, 8);
        let per = 2 * rng.range(1, 8); // even stages per node
        let p = n_nodes * per;
        let l = pair_adjacent_layout(p, n_nodes);
        assert_eq!(l.intra_node_pair_fraction(p), 1.0, "p={p} nodes={n_nodes}");
        // and each node hosts exactly per stages
        for stages in l.stages_per_node() {
            assert_eq!(stages.len() as u64, per);
        }
        // sequential only achieves that with one node
        let seq = sequential_layout(p, n_nodes);
        if n_nodes > 1 {
            assert!(seq.intra_node_pair_fraction(p) < 1.0);
        }
    }
}

#[test]
fn prop_loads_arrive_before_bwd_in_program_order() {
    let mut rng = SplitMix64::new(0x10AD);
    for case in 0..CASES {
        let p = rng.range(2, 16);
        let m = rng.range(1, 96);
        let bp = apply_bpipe(&one_f_one_b(p, m), None);
        for prog in &bp.programs {
            let mut evicted = std::collections::HashSet::new();
            for op in &prog.ops {
                match op.kind {
                    OpKind::Evict => {
                        evicted.insert(op.mb);
                    }
                    OpKind::Load => {
                        evicted.remove(&op.mb);
                    }
                    OpKind::Bwd => {
                        assert!(
                            !evicted.contains(&op.mb),
                            "case {case}: bwd {} while evicted on stage {}",
                            op.mb,
                            prog.stage
                        );
                    }
                    OpKind::Fwd => {}
                }
            }
        }
    }
}
