//! Property-based tests over the discrete-event simulator: conservation
//! and causality invariants that must hold for ANY valid schedule on ANY
//! layout, checked across hundreds of randomized configurations.

use bpipe::bpipe::{apply_bpipe, pair_adjacent_layout, rebalance, sequential_layout, Layout};
use bpipe::config::{paper_experiment, ExperimentConfig};
use bpipe::schedule::{gpipe, interleaved, one_f_one_b, OpKind, Schedule};
use bpipe::sim::{simulate, SimResult};
use bpipe::util::SplitMix64;

const CASES: u64 = 60;

fn random_case(rng: &mut SplitMix64) -> (ExperimentConfig, Schedule, Layout) {
    let mut e = paper_experiment(*rng.choose(&[1, 2, 5, 7, 8, 9, 10])).unwrap();
    let p = *rng.choose(&[4u64, 8]);
    e.parallel.p = p;
    let m = p * rng.range(1, 6);
    e.parallel.microbatch = 1;
    e.parallel.global_batch = m;
    let schedule = match rng.below(6) {
        0 => gpipe(p, m),
        1 => one_f_one_b(p, m),
        2 => interleaved(p, m, rng.range(1, 3)),
        3 => apply_bpipe(&one_f_one_b(p, m), None),
        // the generalized transform on non-1F1B bases (derived bound)
        4 => rebalance(&interleaved(p, m, rng.range(1, 3)), None),
        _ => rebalance(&gpipe(p, m), Some(rng.range(2, m.max(2)))),
    };
    let nodes = if p == 8 && rng.next_f64() < 0.5 { 4 } else { 1 };
    let layout = if rng.next_f64() < 0.5 {
        pair_adjacent_layout(p, nodes)
    } else {
        sequential_layout(p, nodes)
    };
    (e, schedule, layout)
}

fn check_invariants(r: &SimResult, e: &ExperimentConfig, label: &str) {
    // causality: every op has start ≤ end ≤ makespan, no negative times
    for ev in &r.trace {
        assert!(ev.start >= 0.0 && ev.start <= ev.end, "{label}: {ev:?}");
        assert!(ev.end <= r.makespan + 1e-9, "{label}: op past makespan {ev:?}");
    }
    // per-stage compute ops never overlap (one compute stream per stage)
    for stage in 0..e.parallel.p {
        let mut ops: Vec<_> = r
            .trace
            .iter()
            .filter(|t| t.stage == stage && matches!(t.kind, OpKind::Fwd | OpKind::Bwd))
            .collect();
        ops.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
        for w in ops.windows(2) {
            assert!(
                w[1].start >= w[0].end - 1e-9,
                "{label}: overlapping compute on stage {stage}: {:?} then {:?}",
                w[0],
                w[1]
            );
        }
        // busy time == sum of compute durations
        let sum: f64 = ops.iter().map(|t| t.end - t.start).sum();
        assert!(
            (sum - r.busy[stage as usize]).abs() < 1e-6,
            "{label}: busy-time accounting off on stage {stage}"
        );
        assert!(r.busy[stage as usize] <= r.makespan + 1e-9, "{label}");
    }
    // cross-stage fwd causality: Fwd(s, i, c) starts after Fwd(s−1, i, c) ends
    for ev in &r.trace {
        if ev.kind == OpKind::Fwd && ev.stage > 0 {
            let up = r
                .trace
                .iter()
                .find(|t| {
                    t.kind == OpKind::Fwd
                        && t.stage == ev.stage - 1
                        && t.mb == ev.mb
                        && t.chunk == ev.chunk
                })
                .expect("missing upstream fwd");
            assert!(ev.start >= up.end - 1e-9, "{label}: fwd before its input arrived");
        }
    }
    assert!(r.bubble_fraction >= -1e-9 && r.bubble_fraction < 1.0, "{label}");
    assert!(r.mfu > 0.0 && r.mfu < 1.0, "{label}: mfu {}", r.mfu);
}

#[test]
fn prop_des_invariants_hold_for_random_cases() {
    let mut rng = SplitMix64::new(0xDE5);
    for case in 0..CASES {
        let (e, schedule, layout) = random_case(&mut rng);
        let r = simulate(&e, &schedule, &layout);
        check_invariants(&r, &e, &format!("case {case} ({:?})", schedule.kind));
    }
}

#[test]
fn prop_bpipe_never_slower_than_oom() {
    // BPipe's makespan overhead vs plain 1F1B stays bounded (< 10%) for
    // every feasible paper config on the pair-adjacent layout.
    let mut rng = SplitMix64::new(0xBEEF);
    for _ in 0..CASES {
        let e = paper_experiment(*rng.choose(&[1u32, 2, 4, 5, 7, 9])).unwrap();
        let m = e.parallel.num_microbatches();
        let layout = pair_adjacent_layout(e.parallel.p, e.cluster.n_nodes);
        let plain = simulate(&e, &one_f_one_b(e.parallel.p, m), &layout);
        let bp = simulate(&e, &apply_bpipe(&one_f_one_b(e.parallel.p, m), None), &layout);
        let overhead = bp.makespan / plain.makespan - 1.0;
        assert!(
            (-1e-9..0.10).contains(&overhead),
            "exp {:?}: BPipe overhead {overhead:.4}",
            e.id
        );
    }
}

#[test]
fn prop_memory_never_exceeds_1f1b_model() {
    // DES-tracked high-water ≤ the analytic worst case for every stage.
    // BPipe rows get one extra transient activation slot of headroom:
    // the conservative timeline counts a load-start that coincides with
    // a backward's retire-end as both resident (allocations before frees
    // at equal timestamps).  Plain rows must match the model exactly.
    let mut rng = SplitMix64::new(0x314159);
    for _ in 0..CASES {
        let e = paper_experiment(rng.range(1, 10) as u32).unwrap();
        let r = bpipe::sim::simulate_experiment(&e);
        let mm = bpipe::model::memory::MemoryModel::new(&e);
        for s in 0..e.parallel.p {
            let cap = if e.bpipe {
                mm.peak_bytes_bpipe(s) + mm.activation_bytes_per_microbatch(s)
            } else {
                mm.peak_bytes_1f1b(s)
            };
            assert!(
                r.mem_high_water[s as usize] <= cap,
                "exp {:?} stage {s}: {} > {}",
                e.id,
                r.mem_high_water[s as usize],
                cap
            );
        }
    }
}

#[test]
fn trace_csv_is_complete() {
    let e = paper_experiment(8).unwrap();
    let r = bpipe::sim::simulate_experiment(&e);
    let csv = bpipe::sim::engine::trace_to_csv(&r.trace);
    assert_eq!(csv.lines().count(), r.trace.len() + 1);
    assert!(csv.starts_with("stage,kind,mb,chunk,start,end"));
    assert!(csv.contains("Evict"));
}
