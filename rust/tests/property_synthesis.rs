//! Property suite for `schedule::synthesize` (ISSUE 8 satellite): over
//! randomized shapes — pipeline depth, microbatch count, heterogeneous
//! per-stage memory caps, all drawn from a splitmix64 stream with a
//! pinned seed so every run (and the validated Python mirror that
//! derived the expectations) sees the same cases — the synthesizer must
//! only ever emit schedules that are
//!
//! 1. validator-clean (`schedule::validate`),
//! 2. clean through the full static-analyzer gate
//!    (`analysis::check_plan`: zero error-level diagnostics), and
//! 3. actually within budget when *executed*: the DES's dynamic
//!    per-stage stash high-water respects the stash budgets, and the
//!    byte high-water respects the byte caps the budgets came from.
//!
//! The caps are built so that `stash_count_caps` recovers the drawn
//! budget vector exactly (`cap[s] = weights/opt + reserved +
//! counts[s]·act`), making the third property an exact round-trip, not
//! a tolerance check.

use bpipe::analysis::{check_plan, ChannelCaps, Severity};
use bpipe::bpipe::{pair_adjacent_layout, sequential_layout};
use bpipe::config::paper_experiment;
use bpipe::coordinator::RebalancePlan;
use bpipe::model::memory::MemoryModel;
use bpipe::schedule::{try_synthesize, validate, ScheduleKind};
use bpipe::sim::{CostModel, SimOptions, SimWorkspace};

/// splitmix64 — tiny, dependency-free, and trivially mirrored in the
/// Python harness that derived the expected-clean verdicts.
struct SplitMix64(u64);

impl SplitMix64 {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

const CASES: usize = 300;
const SEED: u64 = 0xB1BE;

#[test]
fn synthesized_schedules_are_always_clean_and_within_caps() {
    let base = paper_experiment(8).unwrap();
    let mut rng = SplitMix64(SEED);
    let mut ws = SimWorkspace::new();

    for case in 0..CASES {
        let r1 = rng.next();
        let r2 = rng.next();
        let r3 = rng.next();
        let p = 2 + r1 % 7; // 2..=8
        let m = 1 + r2 % 24; // 1..=24
        let counts: Vec<u64> = (0..p).map(|s| 1 + ((r3 >> (8 * s)) % 6)).collect();

        // reshape the experiment to this depth; the memory model splits
        // layers as l / p, so any 2..=8 is well-formed
        let mut e = base.clone();
        e.parallel.p = p;
        let mm = MemoryModel::new(&e);
        let act = mm.activation_bytes_per_microbatch(0);
        let caps: Vec<u64> = counts
            .iter()
            .enumerate()
            .map(|(s, &c)| mm.weight_opt_bytes(s as u64) + e.cluster.reserved_bytes + c * act)
            .collect();

        let cost = CostModel::new(&e);
        let s = try_synthesize(p, m, &caps, &cost)
            .unwrap_or_else(|err| panic!("case {case} (p={p} m={m} counts {counts:?}): {err}"));

        // contract: stamped kind + the recovered budgets as stage bounds
        assert_eq!(s.kind, ScheduleKind::Synthesized, "case {case}");
        assert_eq!(s.stage_bounds.as_deref(), Some(&counts[..]), "case {case}");

        // 1. validator-clean
        validate(&s).unwrap_or_else(|err| {
            panic!("case {case} (p={p} m={m} counts {counts:?}): validator: {err}")
        });

        // 2. full static gate: zero error-level findings
        let chan = ChannelCaps::for_run(m, s.chunks);
        let diags = check_plan(&s, &RebalancePlan::Off, &chan);
        let errors: Vec<_> =
            diags.iter().filter(|d| d.severity == Severity::Error).collect();
        assert!(
            errors.is_empty(),
            "case {case} (p={p} m={m} counts {counts:?}): {errors:?}"
        );

        // 3. the executed schedule honors the budgets it was built under
        let layout = if e.cluster.n_nodes >= 1 && p % e.cluster.n_nodes == 0 {
            pair_adjacent_layout(p, e.cluster.n_nodes)
        } else {
            sequential_layout(p, 1)
        };
        let stats = ws.run(&e, &s, &layout, SimOptions { trace: false, warm: false, recompute: false });
        assert_eq!(stats.oom_stage, None, "case {case}: DES reported OOM");
        for (stage, (&hw, &budget)) in ws.stash_high_water().iter().zip(&counts).enumerate() {
            assert!(
                hw <= budget as i64,
                "case {case} stage {stage}: stash high-water {hw} > budget {budget} \
                 (counts {counts:?}, all {:?})",
                ws.stash_high_water()
            );
        }
        for (stage, (&bytes, &cap)) in ws.mem_high_water().iter().zip(&caps).enumerate() {
            assert!(
                bytes <= cap,
                "case {case} stage {stage}: {bytes} B > cap {cap} B"
            );
        }
    }
}

#[test]
fn fuzz_shapes_cover_the_intended_ranges() {
    // the suite above is only as strong as its sampling: re-derive the
    // same stream and check it actually exercises every depth and a wide
    // spread of budget vectors (guards against a silent RNG change)
    let mut rng = SplitMix64(SEED);
    let mut depths = std::collections::BTreeSet::new();
    let mut shapes = std::collections::BTreeSet::new();
    for _ in 0..CASES {
        let r1 = rng.next();
        let r2 = rng.next();
        let r3 = rng.next();
        let p = 2 + r1 % 7;
        let m = 1 + r2 % 24;
        let counts: Vec<u64> = (0..p).map(|s| 1 + ((r3 >> (8 * s)) % 6)).collect();
        depths.insert(p);
        shapes.insert((p, m, counts));
    }
    assert_eq!(depths.into_iter().collect::<Vec<_>>(), vec![2, 3, 4, 5, 6, 7, 8]);
    assert!(shapes.len() >= 140, "only {} distinct shapes", shapes.len());
}
