//! Golden snapshot of the `bpipe report` deliverable on experiment (8):
//! structure (sections, embedded-figure count, scenario coverage) plus
//! key values verified against the reference implementation — the
//! replication-report equivalent of `golden_engine.rs`.
//!
//! The report must cover at least one W/zig-zag (v > 2) scenario and
//! one per-stage-bounds scenario (the two axes this PR opens), render
//! ≥ 3 embedded SVG figures, and carry the estimator-vs-DES table with
//! the paper's §4 worked-example numbers.

use bpipe::config::paper_experiment;
use bpipe::report::figures;
use bpipe::sim;

#[test]
fn exp8_report_snapshot() {
    let e = paper_experiment(8).unwrap();
    let ranking = sim::sweep(sim::experiment_tasks(&e, 2), 0);
    let bound_tasks: Vec<sim::SweepTask> = sim::bound_sensitivity_tasks(&e, 2)
        .into_iter()
        .filter(|t| t.layout.name == "pair-adjacent")
        .collect();
    let bounds = sim::sweep(bound_tasks, 0);
    let (frontier_cap, frontier) = sim::frontier_outcomes(&e, 2, 0);
    let md = figures::render_replication_report(&e, &ranking, &bounds, frontier_cap, &frontier);

    // -- structure ----------------------------------------------------
    assert_eq!(md.matches("<svg").count(), 5, "5 embedded SVG figures");
    assert_eq!(md.matches("</svg>").count(), 5);
    for section in [
        "# BPipe replication report",
        "## Figure 1 — per-stage peak memory",
        "## Figure 2 — throughput by scenario",
        "## Figure 3 — bound-sensitivity frontier",
        "## Figure 4 — found-vs-family frontier (tight HBM)",
        "## Estimator vs DES",
    ] {
        assert!(md.contains(section), "missing section {section}");
    }

    // the frontier panel charts the synthesized schedule — under the
    // tight cap it is the only feasible cell, so it must appear by name
    assert!(md.contains("synthesized"), "frontier panel lost the synthesized cell");

    // coverage the acceptance criteria demand: a v>2 W/zig-zag scenario
    // and a per-stage-bounds scenario
    assert!(md.contains("W-shaped"), "missing the v=4 zig-zag scenario");
    assert!(md.contains("1F1B+stage-bounds"), "missing the per-stage-bounds scenario");

    // -- Figure 1 data (reference-pinned, GiB at {:.1}) ----------------
    // stage-0 peaks: plain 1F1B 84.3, rebalanced 77.8, W-shaped 111.8
    for needle in ["| 84.3", "| 77.8", "| 111.8"] {
        assert!(md.contains(needle), "missing figure-1 value {needle}");
    }

    // -- frontier: every family swept from its derived bound down to 2 —
    // 1F1B 5, GPipe 64, interleaved 16, V-shaped 17, W-shaped 66
    for range in ["5..2", "64..2", "16..2", "17..2", "66..2"] {
        assert!(md.contains(range), "missing frontier range {range}");
    }

    // -- estimator vs DES (reference-pinned) ---------------------------
    // the §4 worked example (7)→(8): Eq.4 predicts 1.421, DES measures
    // 1.411 (+0.8% — Eq.4 is an upper bound)
    assert!(md.contains("1.421") && md.contains("1.411"), "GPT-3 transition drifted");
    // LLaMA flash (5)→(6): the paper's negative result, < 1x both ways
    assert!(md.contains("0.958") && md.contains("0.961"), "LLaMA transition drifted");

    // W-shaped base OOMs on exp (8) (four live chunks per stage), while
    // the per-stage-bounds 1F1B fits: the ranking shows both verdicts
    assert!(md.contains("OOM @ stage"));
    assert!(md.contains("fits"));

    // figure tables accompany every chart (the palette's text fallback)
    assert!(md.matches("```text").count() >= 5);

    // every embedded figure is scheme-adaptive: one stylesheet with the
    // dark-mode media query per SVG, neutrals only as classes
    assert_eq!(md.matches("<style>").count(), 5);
    assert_eq!(md.matches("@media (prefers-color-scheme: dark)").count(), 5);
    assert_eq!(md.matches("class=\"surface\"").count(), 5, "one themed canvas per figure");
}

#[test]
fn dark_mode_snapshot_of_one_figure() {
    // a single grouped-bar chart, pinned: both schemes' neutral sets are
    // present, and the dark set lives inside the media query (after it)
    let svg = figures::svg_grouped_bars(
        "snapshot",
        "GiB",
        &["stage 0".into()],
        &[figures::Series { name: "1F1B".into(), slot: 0, values: vec![Some(1.0)] }],
        Some((2.0, "HBM")),
    );
    let media_at = svg.find("@media (prefers-color-scheme: dark)").expect("dark query");
    for (light, dark) in [
        ("#fcfcfb", "#161512"), // surface
        ("#0b0b0b", "#f2f1ed"), // ink
        ("#52514e", "#b6b4ae"), // muted/axis
        ("#e4e3df", "#383632"), // grid
        ("#e34948", "#ff6e6d"), // HBM-limit red
    ] {
        let l = svg.find(light).unwrap_or_else(|| panic!("missing light {light}"));
        let d = svg.find(dark).unwrap_or_else(|| panic!("missing dark {dark}"));
        assert!(l < media_at && d > media_at, "{light}/{dark} scheme placement");
    }
    // marks keep their literal family hue in both schemes
    assert!(svg.contains("#2a78d6"));
}

#[test]
fn report_cells_have_per_stage_memory_for_fig1() {
    // Figure 1 consumes SweepOutcome::per_stage_mem_gib directly — every
    // ranking cell must carry one finite value per pipeline stage
    let e = paper_experiment(8).unwrap();
    let ranking = sim::sweep(sim::experiment_tasks(&e, 2), 0);
    for o in &ranking {
        assert_eq!(o.per_stage_mem_gib.len() as u64, e.parallel.p, "{}", o.scenario);
        assert!(
            o.per_stage_mem_gib.iter().all(|g| g.is_finite() && *g > 0.0),
            "{}: {:?}",
            o.scenario,
            o.per_stage_mem_gib
        );
        let peak = o.per_stage_mem_gib.iter().cloned().fold(0.0f64, f64::max);
        assert!((peak - o.peak_mem_gib).abs() < 1e-9, "{}", o.scenario);
    }
}
