//! Thread-local counting `#[global_allocator]`, shared (via `#[path]`
//! inclusion) by the zero-alloc test binary
//! (`rust/tests/alloc_steady_state.rs`) and the hot-path bench
//! (`benches/runtime_hotpath.rs`), so the two instruments can never
//! drift apart.
//!
//! Counts this thread's `alloc`/`realloc`/`alloc_zeroed` calls —
//! dealloc is free-side and irrelevant to "allocates nothing" — so
//! other threads (workers, feeder, collector, other tests) can't
//! pollute the measurement.  Each including binary gets its own copy of
//! the statics; a binary must include this module at most once.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

pub struct CountingAlloc;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(l)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(p, l, new_size)
    }

    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(l)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

/// This thread's cumulative allocation count.
pub fn allocs() -> u64 {
    ALLOCS.with(|c| c.get())
}
