//! Minimal, offline drop-in replacement for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored crate
//! provides exactly the subset the `bpipe` stack uses: [`Error`],
//! [`Result`], and the [`anyhow!`], [`bail!`], [`ensure!`] macros.
//! Like the real crate, [`Error`] deliberately does NOT implement
//! `std::error::Error` itself so the blanket `From<E>` conversion (what
//! makes `?` work on any std error) does not conflict with the reflexive
//! `From<Error>` impl.

use std::fmt;

/// A boxed dynamic error with Display/Debug passthrough.
pub struct Error(Box<dyn std::error::Error + Send + Sync + 'static>);

impl Error {
    /// Construct an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error(message.to_string().into())
    }

    /// The root error chain, starting at this error's cause.
    pub fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        self.0.source()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // main() exits through Debug; render the human-readable message
        fmt::Display::fmt(&self.0, f)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error(Box::new(e))
    }
}

/// `std::result::Result` specialized to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<u32> {
        let n: u32 = s.parse()?; // From<ParseIntError> via the blanket impl
        ensure!(n < 100, "too big: {n}");
        Ok(n)
    }

    #[test]
    fn question_mark_and_ensure() {
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
        let e = parse("1000").unwrap_err();
        assert_eq!(e.to_string(), "too big: 1000");
    }

    #[test]
    fn bail_and_format() {
        fn f(flag: bool) -> Result<()> {
            if flag {
                bail!("flag was {flag:?}");
            }
            Ok(())
        }
        assert!(f(false).is_ok());
        assert_eq!(f(true).unwrap_err().to_string(), "flag was true");
        let e: Error = anyhow!("plain");
        assert_eq!(format!("{e:?}"), "plain");
    }
}
